"""Typed StageFn contract: partition a real MLLM into per-stage callables.

The pipeline executors (the sequential replay in
``core.modality_parallel.execute_schedule`` and the distributed
``parallel.spmd.build_spmd_runner``) move ONE activation tensor per
stage handoff.  A real MLLM has heterogeneous stage boundaries — an
encoder's hidden state is [B, T_m, d_m], the LLM's is [B, T_c, d_llm],
and the LLM additionally needs the text tokens and labels that no
upstream activation carries.  ``build_mllm_stages`` closes that gap
with a *carrier* encoding plus a typed 3-argument stage function:

    stage_fn(stage_params, x, microbatch) -> y

* The carrier is a single float32 array [B, T_c, d_c] over the merged
  sequence (T_c = ``mllm.merged_length(text_len)``, d_c = max of the
  LLM and encoder widths).  Encoder stages read/write their modality's
  rows in channels [:d_m]; the last encoder stage writes the projected
  output in channels [:d_llm].  Text rows of the *microbatch* carrier
  hold the text token id in channel 0 and the label in channel 1
  (exact in float32: vocab sizes here are far below 2**24).  Because
  modality rows carry raw embeddings in those same channels, token and
  label reads are always masked by the static text mask.
* Stage partitioning follows the executor's simulated graph
  (``executor["sim_graph"]``): stages grouped by ``Stage.module``
  (encoder name or ``"llm"``), validated to tile each module's layers
  contiguously.  Boundary stages own the boundary params — final_ln +
  projector on the last encoder stage, embedding on the first LLM
  stage, final_ln + unembed on the last.
* Frozen flags are preserved: frozen subtrees run under stop_gradient
  inside the stage fn (backward truly skips them), ``frozen_masks``
  mirrors them for AdamW, and ``trainable`` tells the executors which
  stages must produce weight grads even when the cost model assigned
  them no W work (the paper's frozen-encoder + trainable-projector
  configuration).

The sink stage emits per-token NLL in carrier channel 0;
``microbatch_loss`` reduces it so that summing over microbatches and
dividing by their count reproduces ``make_mllm_train_step``'s
cross-entropy exactly (same masked-label construction, same float32
reduction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bam
from repro.models import layers as L
from repro.models import transformer as T


def _stop(tree):
    return jax.tree.map(lax.stop_gradient, tree)


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One pipeline stage of the partitioned MLLM (host-side, static)."""
    kind: str            # "encoder" | "llm"
    module: str          # encoder name, or "llm"
    lo: int              # module-local first layer (inclusive)
    hi: int              # module-local last layer (exclusive)
    first: bool          # first stage of its module chain
    last: bool           # last stage of its module chain
    trainable: bool      # does this stage hold any trainable params?


@dataclasses.dataclass
class StageBundle:
    """Everything the executors need to run a real MLLM: per-stage
    callables + typed per-stage params + the carrier codec."""
    mllm: Any
    specs: List[StageSpec]
    stage_fns: List[Callable]
    text_len: int
    merged_len: int
    d_carrier: int
    # static merge geometry (host numpy)
    bits_np: Any
    pos_np: Any
    emask_np: Any
    is_text_np: Any
    text_pos_np: Any
    slots: Dict[str, Tuple[int, int, int]]   # name -> (offset, n, d_m)

    # -- carrier codec ------------------------------------------------------
    @property
    def n_text(self) -> int:
        return int(self.is_text_np.sum())

    @property
    def trainable(self) -> Tuple[bool, ...]:
        return tuple(s.trainable for s in self.specs)

    def encode_microbatches(self, batch, num_microbatches: int):
        """batch: {"text_tokens" [B,T], "labels" [B,T],
        f"{name}_embeds" [B,n,d_m]} -> carrier [M, B/M, T_c, d_c]."""
        toks = batch["text_tokens"]
        B = toks.shape[0]
        M = int(num_microbatches)
        if B % M != 0:
            raise ValueError(
                f"batch size {B} not divisible by {M} microbatches")
        car = jnp.zeros((B, self.merged_len, self.d_carrier), jnp.float32)
        tpos = jnp.asarray(self.text_pos_np)
        car = car.at[:, tpos, 0].set(toks.astype(jnp.float32))
        car = car.at[:, tpos, 1].set(batch["labels"].astype(jnp.float32))
        for name, (off, n, dm) in sorted(self.slots.items()):
            car = car.at[:, off:off + n, :dm].set(
                batch[f"{name}_embeds"].astype(jnp.float32))
        return car.reshape(M, B // M, self.merged_len, self.d_carrier)

    def microbatch_loss(self, y):
        """Sink-stage output -> scalar.  Summed over the M microbatches
        this equals M x the full-batch reference cross-entropy (the
        text count per sample is static), so callers scale by 1/M."""
        n = max(self.n_text, 1)
        return jnp.sum(y[..., 0].astype(jnp.float32)) / (y.shape[0] * n)

    # -- params -------------------------------------------------------------
    def partition(self, params) -> List[Any]:
        """Full MLLM param tree -> per-stage param trees (plan order)."""
        out = []
        for sp in self.specs:
            if sp.kind == "encoder":
                src = params["encoders"][sp.module]
                st = {"layers": jax.tree.map(
                    lambda a, sp=sp: a[sp.lo:sp.hi], src["module"]["layers"])}
                if sp.last:
                    st["final_ln"] = src["module"]["final_ln"]
                    st["projector"] = src["projector"]
            else:
                src = params["llm"]
                st = {"layers": jax.tree.map(
                    lambda a, sp=sp: a[sp.lo:sp.hi], src["layers"])}
                if sp.first:
                    st["embed"] = src["embed"]
                if sp.last:
                    st["final_ln"] = src["final_ln"]
                    if not self.mllm.llm_cfg.tie_embeddings:
                        st["unembed"] = src["unembed"]
            out.append(st)
        return out

    def unpartition(self, stage_params: Sequence[Any]):
        """Exact inverse of ``partition`` (stage layer slices tile each
        module, so concatenation reconstructs the stacked layers)."""
        by_module: Dict[str, List[Tuple[StageSpec, Any]]] = {}
        for sp, st in zip(self.specs, stage_params):
            by_module.setdefault(sp.module, []).append((sp, st))
        params: Dict[str, Any] = {"encoders": {}}
        for module, parts in by_module.items():
            parts = sorted(parts, key=lambda p: p[0].lo)
            layers = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0),
                *[st["layers"] for _, st in parts])
            last = parts[-1][1]
            if module == "llm":
                llm = {"embed": parts[0][1]["embed"], "layers": layers,
                       "final_ln": last["final_ln"]}
                if not self.mllm.llm_cfg.tie_embeddings:
                    llm["unembed"] = last["unembed"]
                params["llm"] = llm
            else:
                params["encoders"][module] = {
                    "module": {"layers": layers,
                               "final_ln": last["final_ln"]},
                    "projector": last["projector"],
                }
        return params

    def frozen_masks(self, stage_params: Sequence[Any]) -> List[Any]:
        """Per-stage bool trees (True = frozen) mirroring the frozen
        flags — feed straight into AdamW's frozen masking."""
        out = []
        for sp, st in zip(self.specs, stage_params):
            if sp.kind == "encoder":
                enc = self.mllm.encoders[sp.module]
                mask = {"layers": jax.tree.map(
                    lambda _: enc.frozen_module, st["layers"])}
                if sp.last:
                    mask["final_ln"] = jax.tree.map(
                        lambda _: enc.frozen_module, st["final_ln"])
                    mask["projector"] = jax.tree.map(
                        lambda _: enc.frozen_projector, st["projector"])
            else:
                mask = jax.tree.map(lambda _: self.mllm.frozen_llm, st)
            out.append(mask)
        return out

    # -- checkpoint manifest metadata ---------------------------------------
    @property
    def layout_meta(self) -> Dict[str, Any]:
        """JSON-able stage layout recorded in checkpoint manifests so
        ``--resume`` can verify it is adopting a compatible layout."""
        return {
            "text_len": self.text_len,
            "merged_len": self.merged_len,
            "d_carrier": self.d_carrier,
            "stages": [dataclasses.asdict(s) for s in self.specs],
        }


# ---------------------------------------------------------------------------
# Stage grouping from the simulated graph
# ---------------------------------------------------------------------------

def _group_stages(mllm, graph) -> List[StageSpec]:
    per_module: Dict[str, List[int]] = {}
    for i, st in enumerate(graph.stages):
        per_module.setdefault(st.module, []).append(i)
    specs: List[StageSpec] = [None] * len(graph.stages)   # type: ignore
    for module, idxs in per_module.items():
        if module == "llm":
            n_layers = mllm.llm_cfg.num_layers
        elif module in mllm.encoders:
            n_layers = mllm.encoders[module].cfg.num_layers
        else:
            raise ValueError(
                f"graph stage module {module!r} is not an encoder of this "
                f"MLLM (encoders: {sorted(mllm.encoders)}) nor 'llm'")
        idxs = sorted(idxs, key=lambda i: graph.stages[i].layer_range[0])
        want = 0
        for k, i in enumerate(idxs):
            lo, hi = graph.stages[i].layer_range
            if lo != want or hi < lo:
                raise ValueError(
                    f"stages of module {module!r} do not tile its layers "
                    f"contiguously: got range ({lo}, {hi}) expecting "
                    f"lo={want}")
            want = hi
            first, last = (k == 0), (k == len(idxs) - 1)
            if module == "llm":
                trainable = not mllm.frozen_llm
            else:
                enc = mllm.encoders[module]
                trainable = (not enc.frozen_module) or \
                    (last and not enc.frozen_projector)
            specs[i] = StageSpec(
                kind="llm" if module == "llm" else "encoder",
                module=module, lo=lo, hi=hi, first=first, last=last,
                trainable=trainable)
        if want != n_layers:
            raise ValueError(
                f"stages of module {module!r} cover layers [0, {want}) "
                f"but the module has {n_layers}")
    return specs


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------

def build_mllm_stages(mllm, executor: Dict[str, Any], *,
                      text_len: int) -> StageBundle:
    """Partition ``mllm`` per the executor contract's simulated graph
    into a :class:`StageBundle` whose ``stage_fns``/``partition`` feed
    both ``execute_schedule`` and ``build_spmd_runner``."""
    graph = executor["sim_graph"]
    specs = _group_stages(mllm, graph)
    llm_cfg = mllm.llm_cfg
    if llm_cfg.tie_embeddings and \
            sum(1 for s in specs if s.kind == "llm") > 1:
        raise ValueError(
            "tie_embeddings requires the LLM to be a single pipeline "
            "stage (embedding and head live on different stages)")

    # static merge geometry — constructed exactly as build_merge does
    layout = mllm.layout or mllm.default_layout(text_len)
    total = mllm.merged_length(text_len)
    segs, t_used = [], 0
    for seg in layout:
        if seg[0] == "text":
            segs.append(("text", 0, seg[1]))
            t_used += seg[1]
        else:
            enc = mllm.encoders[seg[0]]
            segs.append(("mod", enc.modality_id, enc.num_tokens))
    if t_used != text_len:
        raise ValueError(f"layout text length {t_used} != {text_len}")
    bits_np, pos_np = bam.build_sample_bits(segs, total)
    emask_np = np.zeros((total,), bool)
    slots: Dict[str, Tuple[int, int, int]] = {}
    off = 0
    for seg in layout:
        if seg[0] == "text":
            off += seg[1]
        else:
            enc = mllm.encoders[seg[0]]
            slots[seg[0]] = (off, enc.num_tokens, enc.cfg.d_model)
            emask_np[off:off + enc.num_tokens] = True
            off += enc.num_tokens
    is_text_np = (np.asarray(bits_np) != 0) & (~emask_np)
    text_pos_np = np.where(is_text_np)[0]
    d_llm = llm_cfg.d_model
    d_carrier = max([d_llm] + [e.cfg.d_model
                               for e in mllm.encoders.values()])

    bits_c = jnp.asarray(bits_np)
    pos_c = jnp.asarray(pos_np)
    emask_c = jnp.asarray(emask_np)
    is_text_c = jnp.asarray(is_text_np)

    def make_encoder_fn(sp: StageSpec):
        enc = mllm.encoders[sp.module]
        cfg = enc.cfg
        off, n, dm = slots[sp.module]

        def fn(lp, x, mb):
            h = x[:, off:off + n, :dm].astype(jnp.dtype(cfg.dtype))
            B = h.shape[0]
            pos = jnp.broadcast_to(
                jnp.arange(n, dtype=jnp.int32)[None], (B, n))
            full = jnp.ones((B, 1, n, n), bool)
            layers = _stop(lp["layers"]) if enc.frozen_module \
                else lp["layers"]

            def body(h, lyr):
                def blk(h):
                    hh = L.apply_norm(cfg, lyr["ln1"], h)
                    a, _ = L.run_attention(lyr["attn"], cfg, hh,
                                           q_pos=pos, mask=full,
                                           rope=False)
                    h = h + a
                    hh = L.apply_norm(cfg, lyr["ln2"], h)
                    return h + L.run_mlp(lyr["mlp"], hh, "gelu")
                if cfg.remat:
                    blk = jax.checkpoint(blk)
                return blk(h), None

            h, _ = lax.scan(body, h, layers)
            if not sp.last:
                return jnp.zeros_like(x).at[:, off:off + n, :dm].set(
                    h.astype(x.dtype))
            fl = _stop(lp["final_ln"]) if enc.frozen_module \
                else lp["final_ln"]
            h = L.apply_norm(cfg, fl, h)
            proj = _stop(lp["projector"]) if enc.frozen_projector \
                else lp["projector"]
            out = h @ proj["w1"]
            if "w2" in proj:
                out = jax.nn.gelu(out) @ proj["w2"]
            return jnp.zeros_like(x).at[:, off:off + n, :d_llm].set(
                out.astype(x.dtype))
        return fn

    def make_llm_fn(sp: StageSpec):
        cfg = llm_cfg
        lo, hi = sp.lo, sp.hi

        def fn(lp, x, mb):
            if mllm.frozen_llm:
                lp = _stop(lp)
            B = x.shape[0]
            Tc = x.shape[1]
            batch = {
                "positions": jnp.broadcast_to(pos_c[None], (B, Tc)),
                "bits": jnp.broadcast_to(bits_c[None], (B, Tc)),
            }
            if sp.first:
                # mod rows of the carrier hold raw embeddings in
                # channel 0 — the token read must stay masked
                tokens = jnp.where(is_text_c[None], mb[..., 0],
                                   0.0).astype(jnp.int32)
                h = lp["embed"][tokens]
                if cfg.embed_scale:
                    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
                h = jnp.where(emask_c[None, :, None],
                              x[:, :, :cfg.d_model].astype(h.dtype), h)
            else:
                h = x[:, :, :cfg.d_model].astype(jnp.dtype(cfg.dtype))

            def body(h, xs):
                lyr, i = xs

                def blk(h):
                    out, _, _ = T._block(cfg, lyr, h, batch, i, None)
                    return out
                if cfg.remat:
                    blk = jax.checkpoint(blk)
                return blk(h), None

            h, _ = lax.scan(body, h,
                            (lp["layers"], jnp.arange(lo, hi)))
            if not sp.last:
                return jnp.zeros_like(x).at[:, :, :cfg.d_model].set(
                    h.astype(x.dtype))
            h = L.apply_norm(cfg, lp["final_ln"], h)
            w = lp["embed"].T if cfg.tie_embeddings else lp["unembed"]
            logits = h @ w
            if cfg.final_softcap:
                logits = jnp.tanh(logits / cfg.final_softcap) \
                    * cfg.final_softcap
            logits = logits.astype(jnp.float32)
            labels = jnp.where(is_text_c[None], mb[..., 1],
                               0.0).astype(jnp.int32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(
                logits, labels[..., None], axis=-1)[..., 0]
            nll = (lse - ll) * is_text_c[None].astype(jnp.float32)
            return jnp.zeros_like(x).at[:, :, 0].set(
                nll.astype(x.dtype))
        return fn

    fns = [make_encoder_fn(sp) if sp.kind == "encoder" else make_llm_fn(sp)
           for sp in specs]
    return StageBundle(
        mllm=mllm, specs=specs, stage_fns=fns, text_len=text_len,
        merged_len=total, d_carrier=d_carrier, bits_np=np.asarray(bits_np),
        pos_np=np.asarray(pos_np), emask_np=emask_np,
        is_text_np=is_text_np, text_pos_np=text_pos_np, slots=slots)
