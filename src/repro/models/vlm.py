"""Qwen2-VL language backbone (arXiv:2409.12191) — M-RoPE + merged
vision tokens.

The ViT/patch-merger frontend is the allowed stub: ``input_specs()``
provides precomputed patch embeddings ``[B, n_patches, d_model]`` plus an
image grid (t, h, w). This module builds the merged multimodal batch —
BAM bitfields (vision tokens bidirectional within the image stream, text
causal; exactly the paper's "encoder outputs embedded" EE mask) and the
3-D M-RoPE position ids — then delegates to the dense transformer.

Dynamic resolution: ``make_vlm_batch`` takes per-sample grids; the
assigned dry-run shapes use a fixed grid, but nothing here assumes it.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bam
from repro.models import transformer as T

VISION = 1  # modality bit for the vision stream

init = T.init
init_cache = T.init_cache


def mrope_positions(seq_len: int, img_start: int, grid: tuple[int, int, int]):
    """Build [3, T] (temporal, h, w) position ids for one sample with one
    image of ``grid`` = (t, h, w) patches starting at ``img_start``.
    Text positions: all three streams equal (standard RoPE degenerate).
    Vision positions: temporal/h/w indices within the grid, offset by the
    text position where the image is embedded."""
    gt, gh, gw = grid
    n_img = gt * gh * gw
    pos = np.zeros((3, seq_len), np.int32)
    # leading text
    for k in range(3):
        pos[k, :img_start] = np.arange(img_start)
    # image block
    t_ids = np.repeat(np.arange(gt), gh * gw)
    h_ids = np.tile(np.repeat(np.arange(gh), gw), gt)
    w_ids = np.tile(np.arange(gw), gt * gh)
    pos[0, img_start:img_start + n_img] = img_start + t_ids
    pos[1, img_start:img_start + n_img] = img_start + h_ids
    pos[2, img_start:img_start + n_img] = img_start + w_ids
    # trailing text continues after max used position
    nxt = img_start + max(gt, gh, gw)
    tail = seq_len - (img_start + n_img)
    for k in range(3):
        pos[k, img_start + n_img:] = nxt + np.arange(tail)
    return pos


def make_vlm_batch(tokens, patch_embeds, img_start: int,
                   grid: tuple[int, int, int], d_model: int):
    """tokens: [B,T] (image positions hold a placeholder id);
    patch_embeds: [B, n_img, d]. Returns a transformer batch with merged
    embeddings, BAM bits, sequential positions, and M-RoPE pos3."""
    B, T_ = tokens.shape
    n_img = int(np.prod(grid))
    assert patch_embeds.shape[1] == n_img

    seg = [("text", 0, img_start), ("mod", VISION, n_img),
           ("text", 0, T_ - img_start - n_img)]
    bits_np, pos_np = bam.build_sample_bits(seg, T_)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T_))
    positions = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T_))

    embed_mask_np = np.zeros((T_,), bool)
    embed_mask_np[img_start:img_start + n_img] = True
    embed_mask = jnp.broadcast_to(jnp.asarray(embed_mask_np)[None], (B, T_))

    inputs_embeds = jnp.zeros((B, T_, d_model), patch_embeds.dtype)
    inputs_embeds = jax.lax.dynamic_update_slice(
        inputs_embeds, patch_embeds, (0, img_start, 0))

    pos3_np = mrope_positions(T_, img_start, grid)
    pos3 = jnp.broadcast_to(jnp.asarray(pos3_np)[:, None], (3, B, T_))

    return {
        "tokens": tokens,
        "positions": positions,
        "bits": bits,
        "inputs_embeds": inputs_embeds,
        "embed_mask": embed_mask,
        "pos3": pos3,
    }


def forward(params, cfg: ModelConfig, batch):
    return T.forward(params, cfg, batch)


def decode_step(params, cfg: ModelConfig, cache, batch):
    return T.decode_step(params, cfg, cache, batch)
