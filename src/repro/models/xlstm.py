"""xLSTM backbone (arXiv:2405.04517): mLSTM + sLSTM blocks.

* **mLSTM** — matrix-memory LSTM with exponential gating. Training uses
  the stabilized *parallel (quadratic) form* (attention-like, MXU
  friendly); decode uses the recurrent form with per-head state
  (C [hd,hd], n [hd], m scalar). Attention-free ⇒ legal for long_500k.
* **sLSTM** — scalar-memory recurrent LSTM with exponential gating and
  block-diagonal recurrent weights; training runs a ``lax.scan`` over
  time (sequential by construction — the paper's own formulation).

Block layout follows the paper's residual pre-norm structure; the
``cfg.xlstm.slstm_at`` indices select sLSTM blocks, the rest are mLSTM
(xLSTM[a:b] notation). d_ff == 0 in the assigned config: blocks carry
their own up/down projections instead of a separate FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    x = cfg.xlstm
    d = cfg.d_model
    dm = int(d * x.proj_factor_m)
    nh = cfg.num_heads
    return d, dm, nh, dm // nh


def mlstm_layer_init(key, cfg: ModelConfig, dtype):
    d, dm, nh, hd = _dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "ln": L.norm_init(cfg, d, dtype),
        "w_up": L.dense_init(ks[0], d, dm, dtype),
        "w_gate_up": L.dense_init(ks[1], d, dm, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.xlstm.conv_kernel, dm))
                   * 0.02).astype(dtype),
        "conv_b": jnp.zeros((dm,), dtype),
        "wq": L.dense_init(ks[3], dm, dm, dtype),
        "wk": L.dense_init(ks[4], dm, dm, dtype),
        "wv": L.dense_init(ks[5], dm, dm, dtype),
        "wi": L.dense_init(ks[6], dm, nh, dtype),
        "wf": L.dense_init(ks[7], dm, nh, dtype),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),  # open forget gates
        "head_ln": L.norm_init(cfg, dm, dtype),
        "w_down": L.dense_init(ks[8], dm, d, dtype),
    }


def slstm_layer_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    x = cfg.xlstm
    dff = int(d * x.proj_factor_s)
    ks = jax.random.split(key, 8)
    p = {
        "ln": L.norm_init(cfg, d, dtype),
        "conv_w": (jax.random.normal(ks[0], (x.conv_kernel, d)) * 0.02
                   ).astype(dtype),
        "conv_b": jnp.zeros((d,), dtype),
        # input weights for z,i,f,o
        "w_zifo": L.dense_init(ks[1], d, 4 * d, dtype),
        # block-diagonal recurrent weights per head [4, nh, hd, hd]
        "r_zifo": (jax.random.normal(ks[2], (4, nh, hd, hd)) * 0.02
                   ).astype(dtype),
        "b_zifo": jnp.zeros((4, d), jnp.float32),
        "group_ln": L.norm_init(cfg, d, dtype),
        "ffn": L.mlp_init(ks[3], d, dff, dtype, gated=True),
        "ffn_ln": L.norm_init(cfg, d, dtype),
    }
    return p


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    x = cfg.xlstm
    k_embed, k_m, k_s, k_out = jax.random.split(key, 4)
    n_s = len(x.slstm_at)
    n_m = cfg.num_layers - n_s
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "mlstm_layers": L.stacked_init(
            lambda k: mlstm_layer_init(k, cfg, dtype), k_m, max(n_m, 1)),
        "final_ln": L.norm_init(cfg, cfg.d_model, dtype),
    }
    if n_s:
        params["slstm_layers"] = L.stacked_init(
            lambda k: slstm_layer_init(k, cfg, dtype), k_s, n_s)
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                         dtype)
    return params


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_qkvif(p, cfg: ModelConfig, xn):
    d, dm, nh, hd = _dims(cfg)
    xu = xn @ p["w_up"]
    xg = xn @ p["w_gate_up"]                      # output-gate branch
    from repro.models.mamba2 import _causal_depthwise_conv
    xc = _causal_depthwise_conv(xu, p["conv_w"], p["conv_b"])
    B_, T_ = xn.shape[:2]

    def heads(a):
        return a.reshape(B_, T_, nh, hd)
    q = heads(xc @ p["wq"]) * (hd ** -0.5)
    k = heads(xc @ p["wk"])
    v = heads(xu @ p["wv"])
    log_i = (xc @ p["wi"]).astype(jnp.float32)                   # [B,T,nh]
    log_f = jax.nn.log_sigmoid(
        (xc @ p["wf"]).astype(jnp.float32) + p["f_bias"])        # <= 0
    o_gate = jax.nn.sigmoid(xg.astype(jnp.float32))
    return q, k, v, log_i, log_f, o_gate, xu


def mlstm_parallel(q, k, v, log_i, log_f):
    """Stabilized parallel mLSTM (paper eq. 19-27). All [B,T,nh,*]."""
    f32 = jnp.float32
    fcum = jnp.cumsum(log_f, axis=1)                              # [B,T,nh]
    # dtilde[t,s] = fcum[t] - fcum[s] + log_i[s], s <= t
    dt_mat = fcum[:, :, None, :] - fcum[:, None, :, :] + \
        log_i[:, None, :, :]                                      # [B,t,s,nh]
    T_ = q.shape[1]
    tri = jnp.tril(jnp.ones((T_, T_), bool))[None, :, :, None]
    dt_mat = jnp.where(tri, dt_mat, -jnp.inf)
    m = jnp.max(dt_mat, axis=2, keepdims=True)                    # [B,t,1,nh]
    D = jnp.exp(dt_mat - m)                                       # stabilized
    S = jnp.einsum("btnh,bsnh->btsn", q.astype(f32), k.astype(f32)) * D
    norm = jnp.maximum(jnp.abs(jnp.sum(S, axis=2, keepdims=True)),
                       jnp.exp(-m))
    S = S / norm
    return jnp.einsum("btsn,bsnh->btnh", S, v.astype(f32))


def mlstm_chunked(q, k, v, log_i, log_f, chunk: int, state=None):
    """Chunkwise-parallel stabilized mLSTM: quadratic *within* chunks,
    recurrent (C, n, m) state across chunks. Matches ``mlstm_parallel``
    (the oracle) to float tolerance; O(T·c) memory instead of O(T^2).

    Returns (h [B,T,nh,hd], (C, n, m) final state)."""
    f32 = jnp.float32
    B_, T_, nh, hd = q.shape
    c = min(chunk, T_)
    assert T_ % c == 0, (T_, c)
    nc = T_ // c
    NEG = jnp.asarray(-1e30, f32)

    def chunkify(a):
        return jnp.moveaxis(a.reshape(B_, nc, c, *a.shape[2:]), 1, 0)

    qc, kc, vc = chunkify(q.astype(f32)), chunkify(k.astype(f32)), \
        chunkify(v.astype(f32))
    lic, lfc = chunkify(log_i), chunkify(log_f)               # [nc,B,c,nh]

    if state is None:
        C0 = jnp.zeros((B_, nh, hd, hd), f32)
        n0 = jnp.zeros((B_, nh, hd), f32)
        m0 = jnp.full((B_, nh), NEG, f32)
    else:
        C0, n0, m0 = state

    tril = jnp.tril(jnp.ones((c, c), bool))[None, :, :, None]

    def step(carry, xs):
        C, n, m = carry
        qz, kz, vz, li, lf = xs                               # [B,c,...]
        fcum = jnp.cumsum(lf, axis=1)                         # [B,c,nh]
        # local matrix exponents dt[t,s] = fcum_t - fcum_s + li_s
        dt_mat = fcum[:, :, None, :] - fcum[:, None, :, :] + li[:, None]
        dt_mat = jnp.where(tril, dt_mat, NEG)
        local_max = jnp.max(dt_mat, axis=2)                   # [B,c,nh]
        m_inter = m[:, None, :] + fcum                        # [B,c,nh]
        m_t = jnp.maximum(m_inter, local_max)
        # intra contributions
        S = jnp.einsum("btnh,bsnh->btsn", qz, kz) * \
            jnp.exp(dt_mat - m_t[:, :, None, :])
        h_num = jnp.einsum("btsn,bsnd->btnd", S, vz)
        # normalizer uses plain decay weights (no q·k)
        w_dec = jnp.exp(dt_mat - m_t[:, :, None, :])          # [B,t,s,nh]
        n_vec = jnp.einsum("btsn,bsnh->btnh", w_dec, kz)
        # inter contributions from carried state
        scale = jnp.exp(m_inter - m_t)[..., None]             # [B,c,nh,1]
        h_num = h_num + scale * jnp.einsum("btnh,bnhd->btnd", qz, C)
        n_vec = n_vec + scale * n[:, None]
        denom = jnp.maximum(
            jnp.abs(jnp.sum(n_vec * qz, axis=-1, keepdims=True)),
            jnp.exp(-m_t)[..., None])
        h = h_num / denom
        # state update to end of chunk
        w_end = fcum[:, -1:, :] - fcum + li                   # [B,c,nh]
        m_end_inter = m + fcum[:, -1]
        m_new = jnp.maximum(m_end_inter, jnp.max(w_end, axis=1))
        we = jnp.exp(w_end - m_new[:, None, :])
        C = jnp.exp(m_end_inter - m_new)[:, :, None, None] * C + \
            jnp.einsum("bsn,bsnh,bsnd->bnhd", we, kz, vz)
        n = jnp.exp(m_end_inter - m_new)[:, :, None] * n + \
            jnp.einsum("bsn,bsnh->bnh", we, kz)
        return (C, n, m_new), h

    (C, n, m), hs = lax.scan(step, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B_, nc * c, nh, hd)
    return h, (C, n, m)


def mlstm_block(p, cfg: ModelConfig, x):
    d, dm, nh, hd = _dims(cfg)
    xn = L.apply_norm(cfg, p["ln"], x)
    q, k, v, log_i, log_f, o_gate, xu = _mlstm_qkvif(p, cfg, xn)
    T_ = q.shape[1]
    if T_ % cfg.xlstm.chunk == 0 and T_ > cfg.xlstm.chunk:
        h, _ = mlstm_chunked(q, k, v, log_i, log_f, cfg.xlstm.chunk)
    else:
        h = mlstm_parallel(q, k, v, log_i, log_f)
    h = h.reshape(*h.shape[:-2], dm)
    h = L.rmsnorm(h.astype(x.dtype), p["head_ln"]["w"])
    h = (h.astype(jnp.float32) * o_gate).astype(x.dtype)
    return x + h @ p["w_down"]


def mlstm_step(p, cfg: ModelConfig, x, state):
    """Recurrent decode step. state: (C [B,nh,hd,hd], n [B,nh,hd],
    m [B,nh], conv [B,k-1,dm])."""
    d, dm, nh, hd = _dims(cfg)
    C, n, m, conv = state
    f32 = jnp.float32
    xn = L.apply_norm(cfg, p["ln"], x)
    xu = xn @ p["w_up"]                                           # [B,1,dm]
    xg = xn @ p["w_gate_up"]
    window = jnp.concatenate([conv, xu], axis=1)                  # [B,k,dm]
    new_conv = window[:, 1:]
    wc = p["conv_w"].astype(f32)
    xc = jnp.sum(window.astype(f32) * wc[None], axis=1, keepdims=True)
    xc = jax.nn.silu(xc + p["conv_b"].astype(f32)).astype(x.dtype)

    def heads(a):
        return a.reshape(a.shape[0], nh, hd)
    q = heads((xc @ p["wq"])[:, 0]) * (hd ** -0.5)
    k = heads((xc @ p["wk"])[:, 0])
    v = heads((xu @ p["wv"])[:, 0])
    log_i = ((xc @ p["wi"])[:, 0]).astype(f32)                    # [B,nh]
    log_f = jax.nn.log_sigmoid(
        ((xc @ p["wf"])[:, 0]).astype(f32) + p["f_bias"])
    m_new = jnp.maximum(log_f + m, log_i)
    a = jnp.exp(log_f + m - m_new)[:, :, None]
    b = jnp.exp(log_i - m_new)[:, :, None]
    C = a[..., None] * C + b[..., None] * jnp.einsum(
        "bnh,bnd->bnhd", k.astype(f32), v.astype(f32))
    n = a * n + b * k.astype(f32)
    num = jnp.einsum("bnh,bnhd->bnd", q.astype(f32), C)
    den = jnp.maximum(jnp.abs(jnp.sum(n * q.astype(f32), axis=-1,
                                      keepdims=True)), jnp.exp(-m_new)[..., None])
    h = (num / den).reshape(x.shape[0], 1, dm)
    h = L.rmsnorm(h.astype(x.dtype), p["head_ln"]["w"])
    o_gate = jax.nn.sigmoid(xg.astype(f32))
    h = (h.astype(f32) * o_gate).astype(x.dtype)
    return x + h @ p["w_down"], (C, n, m_new, new_conv)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_cell(p, cfg: ModelConfig, zifo_x, state):
    """One timestep. zifo_x: [B, 4d] pre-computed input contributions.
    state: (c, n, h, m) each [B, d] (m: [B, nh])."""
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    f32 = jnp.float32
    c, n, h, m = state
    hh = h.reshape(-1, nh, hd)
    rec = jnp.einsum("bnh,gnhd->gbnd", hh.astype(f32),
                     p["r_zifo"].astype(f32)).reshape(4, -1, d)
    pre = zifo_x.reshape(-1, 4, d).transpose(1, 0, 2).astype(f32) + \
        rec + p["b_zifo"][:, None, :]
    z_p, i_p, f_p, o_p = pre
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    log_i = i_p.reshape(-1, nh, hd)
    log_f = jax.nn.log_sigmoid(f_p).reshape(-1, nh, hd)
    m_new = jnp.maximum(log_f + m[..., None],
                        log_i).max(-1)                            # [B,nh]
    a = jnp.exp(log_f + m[..., None] - m_new[..., None]).reshape(-1, d)
    b = jnp.exp(log_i - m_new[..., None]).reshape(-1, d)
    c = a * c + b * z
    n = a * n + b
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new)


def slstm_block(p, cfg: ModelConfig, x, state=None, step: bool = False,
                conv_state=None):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    B_ = x.shape[0]
    f32 = jnp.float32
    xn = L.apply_norm(cfg, p["ln"], x)
    if step:
        window = jnp.concatenate([conv_state, xn], axis=1)
        new_conv = window[:, 1:]
        wc = p["conv_w"].astype(f32)
        xc = jnp.sum(window.astype(f32) * wc[None], axis=1, keepdims=True)
        xc = jax.nn.silu(xc + p["conv_b"].astype(f32)).astype(x.dtype)
    else:
        from repro.models.mamba2 import _causal_depthwise_conv
        xc = _causal_depthwise_conv(xn, p["conv_w"], p["conv_b"])
        new_conv = None
    zifo = xc @ p["w_zifo"]                                       # [B,T,4d]

    if step:
        assert state is not None
        state = _slstm_cell(p, cfg, zifo[:, 0], state)
        h = state[2][:, None]
    else:
        init = (jnp.zeros((B_, d), f32), jnp.zeros((B_, d), f32),
                jnp.zeros((B_, d), f32), jnp.full((B_, nh), -jnp.inf, f32))

        def scan_fn(s, z_t):
            s = _slstm_cell(p, cfg, z_t, s)
            return s, s[2]

        state, hs = lax.scan(scan_fn, init, jnp.moveaxis(zifo, 1, 0))
        h = jnp.moveaxis(hs, 0, 1)                                # [B,T,d]
    h = L.apply_norm(cfg, p["group_ln"], h.astype(x.dtype))
    x = x + h
    hn = L.apply_norm(cfg, p["ffn_ln"], x)
    x = x + L.run_mlp(p["ffn"], hn, "gelu")
    return x, state, new_conv


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------

def _layer_plan(cfg: ModelConfig):
    """Returns list of ("m"|"s", index-within-kind) per layer."""
    s_at = set(cfg.xlstm.slstm_at)
    plan, mi, si = [], 0, 0
    for i in range(cfg.num_layers):
        if i in s_at:
            plan.append(("s", si))
            si += 1
        else:
            plan.append(("m", mi))
            mi += 1
    return plan


def hidden(params, cfg: ModelConfig, batch):
    x = T.embed_tokens(params, cfg, batch)
    # xLSTM mixes two block types -> per-layer python loop (12 layers;
    # the sLSTM time-scan dominates compile anyway)
    for kind, j in _layer_plan(cfg):
        if kind == "m":
            lp = jax.tree.map(lambda a: a[j], params["mlstm_layers"])

            def blk(x, lp=lp):
                return mlstm_block(lp, cfg, x)
        else:
            lp = jax.tree.map(lambda a: a[j], params["slstm_layers"])

            def blk(x, lp=lp):
                out, _, _ = slstm_block(lp, cfg, x)
                return out
        if cfg.remat:
            blk = jax.checkpoint(blk)
        x = blk(x)
        # pin the residual stream so GSPMD keeps the batch sharded
        # through the chunked-scan reshapes (475 GB/dev replication
        # otherwise under FSDP — EXPERIMENTS.md §Perf-D note)
        from repro.launch import sharding as shd
        x = shd.constrain_residual(x)
    return L.apply_norm(cfg, params["final_ln"], x), \
        {"aux_loss": jnp.float32(0.0)}


def forward(params, cfg: ModelConfig, batch):
    h, aux = hidden(params, cfg, batch)
    return T.unembed(params, cfg, h), aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    d, dm, nh, hd = _dims(cfg)
    n_s = len(cfg.xlstm.slstm_at)
    n_m = cfg.num_layers - n_s
    k = cfg.xlstm.conv_kernel
    c = {
        "m_C": jnp.zeros((n_m, batch, nh, hd, hd), jnp.float32),
        "m_n": jnp.zeros((n_m, batch, nh, hd), jnp.float32),
        "m_m": jnp.zeros((n_m, batch, nh), jnp.float32),
        "m_conv": jnp.zeros((n_m, batch, k - 1, dm), dtype),
    }
    if n_s:
        c.update({
            "s_c": jnp.zeros((n_s, batch, d), jnp.float32),
            "s_n": jnp.zeros((n_s, batch, d), jnp.float32),
            "s_h": jnp.zeros((n_s, batch, d), jnp.float32),
            "s_m": jnp.full((n_s, batch, cfg.num_heads), -jnp.inf,
                            jnp.float32),
            "s_conv": jnp.zeros((n_s, batch, k - 1, d), dtype),
        })
    return c


def decode_step(params, cfg: ModelConfig, cache, batch):
    x = T.embed_tokens(params, cfg, batch)
    new_cache = jax.tree.map(lambda a: a, cache)
    for kind, j in _layer_plan(cfg):
        if kind == "m":
            lp = jax.tree.map(lambda a: a[j], params["mlstm_layers"])
            state = (cache["m_C"][j], cache["m_n"][j], cache["m_m"][j],
                     cache["m_conv"][j])
            x, (C, n, m, conv) = mlstm_step(lp, cfg, x, state)
            new_cache["m_C"] = new_cache["m_C"].at[j].set(C)
            new_cache["m_n"] = new_cache["m_n"].at[j].set(n)
            new_cache["m_m"] = new_cache["m_m"].at[j].set(m)
            new_cache["m_conv"] = new_cache["m_conv"].at[j].set(conv)
        else:
            lp = jax.tree.map(lambda a: a[j], params["slstm_layers"])
            state = (cache["s_c"][j], cache["s_n"][j], cache["s_h"][j],
                     cache["s_m"][j])
            x, state, conv = slstm_block(lp, cfg, x, state=state, step=True,
                                         conv_state=cache["s_conv"][j])
            c_, n_, h_, m_ = state
            new_cache["s_c"] = new_cache["s_c"].at[j].set(c_)
            new_cache["s_n"] = new_cache["s_n"].at[j].set(n_)
            new_cache["s_h"] = new_cache["s_h"].at[j].set(h_)
            new_cache["s_m"] = new_cache["s_m"].at[j].set(m_)
            new_cache["s_conv"] = new_cache["s_conv"].at[j].set(conv)
    h = L.apply_norm(cfg, params["final_ln"], x)
    return T.unembed(params, cfg, h), new_cache
