"""Shared neural-net layer library (pure functional, pytree params).

Every model family in ``repro.models`` builds on these primitives. All
parameters are plain dicts of jnp arrays; init functions take an explicit
PRNG key; apply functions are pure. Layer stacks use ``lax.scan`` over
stacked parameters (leading ``L`` axis) — required for compile
tractability of 28–54-layer models under a 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig

Params = dict


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (vocab, d)) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_init(cfg: ModelConfig, d: int, dtype):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def apply_norm(cfg: ModelConfig, p: Params, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_angles(pos, head_dim: int, theta: float):
    """pos: [..., T] int -> cos/sin [..., T, head_dim//2] fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, pos, theta: float):
    """x: [B, T, H, hd]; pos: [B, T] (or [T]) -> rotated x (split-half form)."""
    hd = x.shape[-1]
    cos, sin = rope_angles(pos, hd, theta)   # [B, T, hd/2]
    cos = cos[..., None, :]                  # [B, T, 1, hd/2]
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, pos3, sections, theta: float):
    """Multimodal RoPE (qwen2-vl, arXiv:2409.12191).

    pos3: [3, B, T] (temporal, height, width) position ids. ``sections``
    partitions the half-dim into (t, h, w) bands; each band rotates by its
    own position stream. For text tokens all three ids are equal, reducing
    to standard RoPE.
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # build per-frequency position selection
    sec_ids = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])                                                   # [half]
    pos3f = pos3.astype(jnp.float32)                     # [3, B, T]
    pos_sel = jnp.take(pos3f, sec_ids, axis=0)           # [half, B, T]
    ang = jnp.moveaxis(pos_sel, 0, -1) * freqs           # [B, T, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masked scaled-dot-product attention (XLA path).
# The Pallas BAM kernel (repro.kernels) implements the same semantics for
# the perf-critical path; `repro.core.bam.allowed_mask` is the single
# source of truth for mask semantics.
# ---------------------------------------------------------------------------

from repro.core.bam import repeat_kv  # noqa: E402  (shared GQA expand)


def sdpa(q, k, v, mask, *, softcap: float = 0.0, scale: Optional[float] = None):
    """q: [B,Tq,H,hd] k/v: [B,Tk,H,hd] mask: broadcastable to [B,H,Tq,Tk] bool."""
    hd = q.shape[-1]
    scale = scale if scale is not None else hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask, logits, neg)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    # rows with no allowed key (padding) -> zero output, not NaN
    any_ok = jnp.any(mask, axis=-1, keepdims=True)
    probs = jnp.where(any_ok, probs, 0.0).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def sdpa_q_chunked(q, k, v, mask_fn, chunk: int, *, softcap: float = 0.0):
    """Flash-style q-chunked attention for the XLA path: queries are
    processed in blocks of ``chunk``; the mask tile is built per block
    by ``mask_fn(start, size)`` so neither the [Tq,Tk] logits nor the
    [Tq,Tk] mask ever materialize (§Perf-D, the prefill memory lever).
    q/k/v: [B,T,H,hd] (k/v already GQA-expanded)."""
    B, Tq, H, hd = q.shape
    assert Tq % chunk == 0, (Tq, chunk)
    nc = Tq // chunk

    def body(_, i):
        qs = lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        mask = mask_fn(i * chunk, chunk)

        def f(qs, mask):
            return sdpa(qs, k, v, mask, softcap=softcap)
        return None, jax.checkpoint(f)(qs, mask)

    _, outs = lax.scan(body, None, jnp.arange(nc))
    # [nc, B, chunk, H, hd] -> [B, Tq, H, hd]
    return jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H, hd)


def causal_mask(q_pos, kv_pos, window: int = 0):
    """q_pos: [B,Tq], kv_pos: [B,Tk] -> [B,1,Tq,Tk] bool."""
    m = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        m &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    return m[:, None]


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.q_dim, dtype),
        "wk": dense_init(ks[1], d, cfg.kv_dim, dtype),
        "wv": dense_init(ks[2], d, cfg.kv_dim, dtype),
        "wo": dense_init(ks[3], cfg.q_dim, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.use_qk_norm:
        p["qnorm"] = jnp.zeros((cfg.head_dim,), dtype)
        p["knorm"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def attn_project_qkv(p: Params, cfg: ModelConfig, x_q, x_kv):
    b, tq, _ = x_q.shape
    tk = x_kv.shape[1]
    q = x_q @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, tq, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, tk, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, tk, cfg.num_kv_heads, cfg.head_dim)
    if cfg.use_qk_norm:
        q = rmsnorm(q, p["qnorm"])
        k = rmsnorm(k, p["knorm"])
    return q, k, v


def run_attention(p: Params, cfg: ModelConfig, x_q, *, x_kv=None, q_pos=None,
                  kv_pos=None, mask=None, mask_fn=None, rope: bool = True,
                  pos3=None, window: int = 0, kv_override=None, bits=None,
                  kv_bits=None):
    """Full attention block. ``mask``: [B,1|H,Tq,Tk] bool or None (causal).
    ``mask_fn(start, size)`` enables the q-chunked path
    (cfg.attn_q_chunk) without materializing the full mask.

    kv_override: (k, v) already-projected cache tensors (decode path).
    bits/kv_bits: BAM bitfields [B,T*]; when given and cfg.attn_impl is
    a kernel impl ("bam_kernel" / "bam_interpret"), attention dispatches
    to the fused Pallas path (repro.kernels.ops.bam_attention — mask
    in-registers, LSE residuals, fused backward) with ``window`` as the
    static sliding window. The decode path (kv_override) stays on XLA.

    Context parallelism: when ``cfg.cp_mesh`` is set and bits are
    given, attention dispatches to ``core.context_parallel
    .cp_attention`` instead — the token axis shards over
    ``cfg.cp_axis``, per-step math follows ``cfg.attn_impl``, and the
    combining-aware custom_vjp keeps the whole thing differentiable.
    Inputs must already be permuted to the ContextPlan layout.
    """
    x_kv = x_q if x_kv is None else x_kv
    b, tq, _ = x_q.shape
    q, k, v = attn_project_qkv(p, cfg, x_q, x_kv)
    if rope:
        # NB: k is projected from x_kv; in every rope=True call site
        # x_kv is x_q (self-attention), so the fresh K rotates by the
        # *query* positions. kv_pos describes already-cached tokens and
        # is only a masking input (they were roped when inserted).
        if pos3 is not None and cfg.mm is not None and cfg.mm.mrope_sections:
            q = apply_mrope(q, pos3, cfg.mm.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, pos3, cfg.mm.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, q_pos, cfg.rope_theta)
            k = apply_rope(k, q_pos, cfg.rope_theta)
    if kv_override is not None:
        k, v = kv_override(k, v)
    elif cfg.cp_mesh is not None and bits is not None:
        # context-parallel dispatch: global arrays in plan layout, the
        # token axis shard_map'd over cfg.cp_axis; differentiable on
        # every impl (combining-aware custom_vjp in the CP bodies).
        from repro.core.context_parallel import cp_attention
        out = cp_attention(
            cfg.cp_mesh, cfg.cp_axis, q, k, v, bits,
            bits if kv_bits is None else kv_bits, q_pos,
            q_pos if kv_pos is None else kv_pos, method=cfg.cp_method,
            softcap=cfg.attn_softcap, window=window, impl=cfg.attn_impl)
        return out.reshape(b, tq, cfg.q_dim) @ p["wo"], (k, v)
    elif cfg.attn_impl != "xla" and bits is not None:
        # fused Pallas BAM path: GQA folded into the kernel's index
        # maps, bitfield mask evaluated in-registers, custom_vjp with
        # (out, lse) residuals — the training hot path.
        from repro.kernels.ops import auto_block, bam_attention
        out = bam_attention(
            q, k, v, bits, bits if kv_bits is None else kv_bits,
            q_pos, q_pos if kv_pos is None else kv_pos,
            softcap=cfg.attn_softcap, window=window, impl=cfg.attn_impl,
            block_q=auto_block(tq), block_k=auto_block(k.shape[1]))
        return out.reshape(b, tq, cfg.q_dim) @ p["wo"], (k, v)
    # n_rep from the actual tensor: decode caches may carry replicated
    # KV heads (cfg.decode_kv_replicate)
    n_rep = cfg.num_heads // k.shape[2]
    kf, vf = repeat_kv(k, n_rep), repeat_kv(v, n_rep)
    chunk = cfg.attn_q_chunk
    if mask_fn is not None and chunk and tq % chunk == 0 and tq > chunk:
        out = sdpa_q_chunked(q, kf, vf, mask_fn, chunk,
                             softcap=cfg.attn_softcap)
    else:
        if mask is None and mask_fn is not None:
            mask = mask_fn(0, tq)
        if mask is None:
            assert q_pos is not None
            mask = causal_mask(q_pos,
                               kv_pos if kv_pos is not None else q_pos,
                               window)
        out = sdpa(q, kf, vf, mask, softcap=cfg.attn_softcap)
    out = out.reshape(b, tq, cfg.q_dim) @ p["wo"]
    return out, (k, v)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, dtype, gated: bool) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d, dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def run_mlp(p: Params, x, act: str):
    up = x @ p["w_up"]
    if "w_gate" in p:
        g = x @ p["w_gate"]
        h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * up
    else:
        h = jax.nn.silu(up) if act == "silu" else jax.nn.gelu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# KV cache (stacked over layers for scan)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                  num_layers: Optional[int] = None):
    L = num_layers if num_layers is not None else cfg.num_layers
    shape = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_update(cache_k, cache_v, k_new, v_new, index):
    """Insert [B, Tnew, Hkv, hd] at position ``index`` (single layer)."""
    k = lax.dynamic_update_slice(cache_k, k_new.astype(cache_k.dtype),
                                 (0, index, 0, 0))
    v = lax.dynamic_update_slice(cache_v, v_new.astype(cache_v.dtype),
                                 (0, index, 0, 0))
    return k, v


def cache_update_ragged(cache_k, cache_v, k_new, v_new, index):
    """Per-row insert for continuous batching: ``index`` is [B] int32
    (each request sits at its own ragged cache offset), ``k_new``/
    ``v_new`` are one-token [B, 1, Hkv, hd]."""
    rows = jnp.arange(cache_k.shape[0])
    k = cache_k.at[rows, index].set(k_new[:, 0].astype(cache_k.dtype))
    v = cache_v.at[rows, index].set(v_new[:, 0].astype(cache_v.dtype))
    return k, v


# ---------------------------------------------------------------------------
# Stacked-layer init helper
# ---------------------------------------------------------------------------

def stacked_init(per_layer_init, key, num_layers: int):
    """vmap a per-layer init over stacked keys -> params with leading L dim."""
    keys = jax.random.split(key, num_layers)
    return jax.vmap(per_layer_init)(keys)


def scan_layers(body, params_stacked, carry, cfg: ModelConfig, *,
                length: Optional[int] = None, extra=None):
    """Run ``carry = body(carry, layer_params, layer_idx, extra)`` over the
    stacked layer params with lax.scan (+ optional remat)."""
    L = length if length is not None else cfg.num_layers
    idx = jnp.arange(L)

    def step(c, xs):
        lp, i = xs
        fn = body
        if cfg.remat:
            fn = jax.checkpoint(body, static_argnums=(), policy=None)
        return fn(c, lp, i, extra), None

    carry, _ = lax.scan(step, carry, (params_stacked, idx))
    return carry
