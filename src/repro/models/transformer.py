"""Dense decoder-only transformer family.

Covers (via ModelConfig flags): starcoder2-7b (GQA+RoPE, layernorm, gelu),
qwen3-1.7b (qk_norm), gemma2-9b (local/global alternation, softcaps,
post-block norms, tied embeddings, embed scale), qwen2.5-14b (QKV bias),
and the qwen2-vl-7b language backbone (M-RoPE via cfg.mm). The MoE family
(repro.models.moe) reuses this skeleton via the ``ffn`` hook.

Interface (shared by all model families in repro.models):
    init(key, cfg)                          -> params
    forward(params, cfg, batch)             -> (logits [B,T,V], aux dict)
    hidden(params, cfg, batch)              -> (final hidden [B,T,d], aux)
    init_cache(cfg, batch, max_len, dtype)  -> cache
    decode_step(params, cfg, cache, batch)  -> (logits [B,1,V], cache)

batch keys: tokens [B,T] int32; positions [B,T] int32; optional
bits [B,T] uint32 (BAM; None => causal); optional inputs_embeds
[B,T,d] + embed_mask [B,T] bool (multimodal merge: where True, take
inputs_embeds instead of the token embedding — Cornstarch's
``cb_before_llm`` modality-token merge); optional pos3 [3,B,T] (M-RoPE).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import bam
from repro.models import layers as L

FFN = Callable  # (layer_params, h [B,T,d]) -> (out [B,T,d], aux scalar)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, dtype, ffn_init=None):
    ks = jax.random.split(key, 6)
    gated = cfg.act == "silu" or cfg.name.startswith("gemma2")
    p = {
        "ln1": L.norm_init(cfg, cfg.d_model, dtype),
        "attn": L.attn_init(ks[0], cfg, dtype),
        "ln2": L.norm_init(cfg, cfg.d_model, dtype),
    }
    if ffn_init is None:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, gated)
    else:
        p["mlp"] = ffn_init(ks[1])
    if cfg.post_block_norm:
        p["post_ln1"] = L.norm_init(cfg, cfg.d_model, dtype)
        p["post_ln2"] = L.norm_init(cfg, cfg.d_model, dtype)
    return p


def init(key, cfg: ModelConfig, ffn_init=None):
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_out = jax.random.split(key, 3)
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "layers": L.stacked_init(
            lambda k: _layer_init(k, cfg, dtype, ffn_init), k_layers,
            cfg.num_layers),
        "final_ln": L.norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                         dtype)
    return params


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _layer_window(cfg: ModelConfig, layer_idx):
    """gemma2 alternation: every cfg.local_global_pattern-th layer is
    global, others use cfg.sliding_window."""
    if cfg.local_global_pattern:
        is_global = (layer_idx % cfg.local_global_pattern) == (
            cfg.local_global_pattern - 1)
        return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)
    return jnp.full((), cfg.sliding_window, jnp.int32)


def _mask_for(cfg: ModelConfig, batch, window, kv_bits=None, kv_pos=None,
              q_slice=None):
    """Lazily build the attention mask (XLA fuses it into the softmax).
    window is a traced scalar (0 = full). q_slice=(start, size) builds
    just that block of query rows (the q-chunked path)."""
    q_pos = batch["positions"]
    kv_pos_full = q_pos if kv_pos is None else kv_pos
    bits = batch.get("bits")
    q_bits = bits
    if q_slice is not None:
        start, size = q_slice
        q_pos = lax.dynamic_slice_in_dim(q_pos, start, size, axis=1)
        if bits is not None:
            q_bits = lax.dynamic_slice_in_dim(bits, start, size, axis=1)
    win_ok = jnp.where(
        window > 0,
        (q_pos[:, :, None] - kv_pos_full[:, None, :]) < window, True)
    if bits is not None:
        kvb = bits if kv_bits is None else kv_bits
        m = bam.allowed_mask(q_bits, kvb, q_pos, kv_pos_full)
        q_text = bam.own_modality(
            q_bits[:, :, None].astype(jnp.uint32)) == bam.TEXT
        m = m & (win_ok | ~q_text)  # window constrains text queries only
        return m[:, None]
    m = kv_pos_full[:, None, :] <= q_pos[:, :, None]
    return (m & win_ok)[:, None]


def _default_ffn(lp, h, cfg):
    return L.run_mlp(lp["mlp"], h, cfg.act), jnp.float32(0.0)


def _block(cfg: ModelConfig, p, x, batch, layer_idx, ffn: Optional[FFN]):
    window = _layer_window(cfg, layer_idx)

    def mask_fn(start, size):
        return _mask_for(cfg, batch, window, q_slice=(start, size))

    # fused Pallas BAM / context-parallel dispatch needs a *static*
    # window; the gemma2 local/global alternation traces it per layer,
    # so that stays XLA (a cp_mesh is ignored there: each device then
    # computes full attention — correct, just not context-parallel).
    kernel_bits = None
    if ((cfg.attn_impl != "xla" or cfg.cp_mesh is not None)
            and batch.get("bits") is not None
            and not cfg.local_global_pattern):
        kernel_bits = batch["bits"]

    h = L.apply_norm(cfg, p["ln1"], x)
    attn_out, kv = L.run_attention(
        p["attn"], cfg, h, q_pos=batch["positions"], mask_fn=mask_fn,
        pos3=batch.get("pos3"), bits=kernel_bits,
        window=cfg.sliding_window if kernel_bits is not None else 0)
    if cfg.post_block_norm:
        attn_out = L.apply_norm(cfg, p["post_ln1"], attn_out)
    x = x + attn_out
    h = L.apply_norm(cfg, p["ln2"], x)
    if ffn is None:
        mlp_out, aux = _default_ffn(p, h, cfg)
    else:
        mlp_out, aux = ffn(p, h, layer_idx)
    if cfg.post_block_norm:
        mlp_out = L.apply_norm(cfg, p["post_ln2"], mlp_out)
    x = x + mlp_out
    if cfg.seq_shard_activations:
        from repro.launch import sharding as shd
        x = shd.constrain_residual(x)
    # kv: the layer's projected+roped K/V — discarded in training
    # (hidden's scan), captured by the serving prefill so prompt K/V
    # can be written straight into the paged decode cache
    return x, aux, kv


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, batch):
    x = params["embed"][batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if batch.get("inputs_embeds") is not None:
        x = jnp.where(batch["embed_mask"][..., None],
                      batch["inputs_embeds"].astype(x.dtype), x)
    return x


def hidden(params, cfg: ModelConfig, batch, ffn: Optional[FFN] = None):
    x = embed_tokens(params, cfg, batch)

    def body(carry, xs):
        x, aux = carry
        lp, i = xs

        def blk(x):
            return _block(cfg, lp, x, batch, i, ffn)
        if cfg.remat:
            blk = jax.checkpoint(blk)
        x, a, _ = blk(x)
        return (x, aux + a), None

    (x, aux), _ = lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["layers"], jnp.arange(cfg.num_layers)))
    return L.apply_norm(cfg, params["final_ln"], x), {"aux_loss": aux}


def unembed(params, cfg: ModelConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = h @ w
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def forward(params, cfg: ModelConfig, batch, ffn: Optional[FFN] = None):
    h, aux = hidden(params, cfg, batch, ffn)
    return unembed(params, cfg, h), aux


# ---------------------------------------------------------------------------
# Decode (serve_step): one new token against a KV cache
# ---------------------------------------------------------------------------

def _cache_cfg(cfg: ModelConfig) -> ModelConfig:
    if cfg.decode_kv_replicate > cfg.num_kv_heads:
        if (cfg.num_heads % cfg.decode_kv_replicate != 0
                or cfg.decode_kv_replicate % cfg.num_kv_heads != 0):
            raise ValueError(
                f"{cfg.name}: decode_kv_replicate="
                f"{cfg.decode_kv_replicate} must divide num_heads="
                f"{cfg.num_heads} and be a multiple of num_kv_heads="
                f"{cfg.num_kv_heads}")
        return cfg.replace(num_kv_heads=cfg.decode_kv_replicate,
                           decode_kv_replicate=0)
    return cfg


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    c = L.init_kv_cache(_cache_cfg(cfg), batch, max_len, dtype)
    c["bits"] = jnp.zeros((batch, max_len), jnp.uint32)
    return c


def decode_step(params, cfg: ModelConfig, cache, batch,
                ffn: Optional[FFN] = None):
    """batch: tokens [B,1], positions [B,1] (= current index), optional
    bits [B,1]. cache: {k,v: [L,B,Tmax,Hkv,hd], bits: [B,Tmax]}."""
    B, _ = batch["tokens"].shape
    Tmax = cache["k"].shape[2]
    cur = batch["positions"][:, 0]                    # [B]
    x = embed_tokens(params, cfg, batch)
    kv_pos = jnp.broadcast_to(jnp.arange(Tmax, dtype=jnp.int32)[None],
                              (B, Tmax))

    q_bits = batch.get("bits")
    if q_bits is None:
        q_bits = jnp.full((B, 1), bam.text_token(), jnp.uint32)
    cache_bits = jnp.where(
        kv_pos < cur[:, None], cache["bits"],
        jnp.where(kv_pos == cur[:, None],
                  jnp.broadcast_to(q_bits, kv_pos.shape), jnp.uint32(0)))

    def body(x, xs):
        lp, ck, cv, i = xs
        window = _layer_window(cfg, i)
        mask = bam.allowed_mask(q_bits, cache_bits, batch["positions"], kv_pos)
        win_ok = jnp.where(
            window > 0,
            (batch["positions"][:, :, None] - kv_pos[:, None, :]) < window,
            True)
        mask = (mask & win_ok)[:, None]
        store = {}

        def kv_override(k, v):
            rep = cfg.decode_kv_replicate
            if rep > k.shape[2]:
                k = L.repeat_kv(k, rep // k.shape[2])
                v = L.repeat_kv(v, rep // v.shape[2])
            # per-row scatter: continuous batching decodes requests at
            # ragged cache offsets, so each row inserts at its own cur
            nk, nv = L.cache_update_ragged(ck, cv, k, v, cur)
            store["k"], store["v"] = nk, nv
            return nk, nv

        h = L.apply_norm(cfg, lp["ln1"], x)
        attn_out, _ = L.run_attention(
            lp["attn"], cfg, h, q_pos=batch["positions"], kv_pos=kv_pos,
            mask=mask, pos3=batch.get("pos3"), kv_override=kv_override)
        if cfg.post_block_norm:
            attn_out = L.apply_norm(cfg, lp["post_ln1"], attn_out)
        x = x + attn_out
        h = L.apply_norm(cfg, lp["ln2"], x)
        if ffn is None:
            mlp_out, _ = _default_ffn(lp, h, cfg)
        else:
            mlp_out, _ = ffn(lp, h, i)
        if cfg.post_block_norm:
            mlp_out = L.apply_norm(cfg, lp["post_ln2"], mlp_out)
        x = x + mlp_out
        return x, (store["k"], store["v"])

    layer_ids = jnp.arange(cfg.num_layers)
    x, (new_k, new_v) = lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], layer_ids))
    h = L.apply_norm(cfg, params["final_ln"], x)
    logits = unembed(params, cfg, h)
    new_bits = cache["bits"].at[jnp.arange(B), cur].set(q_bits[:, 0])
    new_cache = {"k": new_k, "v": new_v, "bits": new_bits}
    return logits, new_cache
