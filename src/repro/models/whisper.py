"""Whisper-style encoder-decoder audio backbone (arXiv:2212.04356).

The mel-spectrogram + 2×conv frontend is the allowed stub:
``input_specs()`` feeds precomputed frame embeddings
``[B, n_frames=1500, d_model]`` directly to the encoder (DESIGN.md §4).

Encoder: bidirectional MHA + gelu MLP, sinusoidal positions, pre-LN.
Decoder: causal self-attention + cross-attention to encoder states.
Deviation (documented): the decoder uses sinusoidal positions instead of
whisper's learned 448-entry table — the assigned decode shapes require
positions up to 32k.

As an MLLM module this is a natural 2-node execution DAG
(encoder → decoder), which is exactly what the frozen-aware pipeline
partitioner (core/pipeline.py) consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import bam
from repro.models import layers as L
from repro.models import transformer as T


def sinusoid_pos(pos, d: int):
    """pos: [B,T] -> [B,T,d] float32 sinusoidal embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) *
                    jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _enc_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.norm_init(cfg, cfg.d_model, dtype),
        "attn": L.attn_init(ks[0], cfg, dtype),
        "ln2": L.norm_init(cfg, cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, gated=False),
    }


def _dec_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    p = _enc_layer_init(ks[0], cfg, dtype)
    p["ln_cross"] = L.norm_init(cfg, cfg.d_model, dtype)
    p["cross"] = L.attn_init(ks[1], cfg, dtype)
    return p


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    e = cfg.encdec
    return {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": L.stacked_init(
            lambda k: _enc_layer_init(k, cfg, dtype), ks[1],
            e.num_encoder_layers),
        "enc_ln": L.norm_init(cfg, cfg.d_model, dtype),
        "layers": L.stacked_init(
            lambda k: _dec_layer_init(k, cfg, dtype), ks[2],
            cfg.num_layers),
        "final_ln": L.norm_init(cfg, cfg.d_model, dtype),
    }  # unembed tied (whisper ties)


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames):
    """frames: [B, T_enc, d] stubbed conv-frontend output."""
    B, Te, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))
    x = frames + sinusoid_pos(pos, cfg.d_model).astype(frames.dtype)
    full = jnp.ones((B, 1, Te, Te), bool)

    def body(x, lp):
        def blk(x):
            h = L.apply_norm(cfg, lp["ln1"], x)
            a, _ = L.run_attention(lp["attn"], cfg, h, q_pos=pos, mask=full,
                                   rope=False)
            x = x + a
            h = L.apply_norm(cfg, lp["ln2"], x)
            return x + L.run_mlp(lp["mlp"], h, "gelu")
        if cfg.remat:
            blk = jax.checkpoint(blk)
        return blk(x), None

    x, _ = lax.scan(body, x, params["enc_layers"])
    return L.apply_norm(cfg, params["enc_ln"], x)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _dec_block(cfg, lp, x, enc_out, batch, self_mask, enc_pos):
    q_pos = batch["positions"]
    h = L.apply_norm(cfg, lp["ln1"], x)
    a, _ = L.run_attention(lp["attn"], cfg, h, q_pos=q_pos, mask=self_mask,
                           rope=False)
    x = x + a
    h = L.apply_norm(cfg, lp["ln_cross"], x)
    B, Te = enc_pos.shape
    cross_mask = jnp.ones((B, 1, h.shape[1], Te), bool)
    a, _ = L.run_attention(lp["cross"], cfg, h, x_kv=enc_out, q_pos=q_pos,
                           kv_pos=enc_pos, mask=cross_mask, rope=False)
    x = x + a
    h = L.apply_norm(cfg, lp["ln2"], x)
    x = x + L.run_mlp(lp["mlp"], h, "gelu")
    if cfg.seq_shard_activations:
        from repro.launch import sharding as shd
        x = shd.constrain_residual(x)
    return x


def forward(params, cfg: ModelConfig, batch):
    """batch: encoder_embeds [B,Te,d]; tokens/positions [B,T]; optional
    bits (BAM over decoder tokens)."""
    enc_out = encode(params, cfg, batch["encoder_embeds"])
    B, Te = enc_out.shape[:2]
    enc_pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))
    q_pos = batch["positions"]
    x = params["embed"][batch["tokens"]]
    x = x + sinusoid_pos(q_pos, cfg.d_model).astype(x.dtype)
    bits = batch.get("bits")
    if bits is not None:
        self_mask = bam.allowed_mask(bits, bits, q_pos, q_pos)[:, None]
    else:
        self_mask = L.causal_mask(q_pos, q_pos)

    def body(x, lp):
        def blk(x):
            return _dec_block(cfg, lp, x, enc_out, batch, self_mask, enc_pos)
        if cfg.remat:
            blk = jax.checkpoint(blk)
        return blk(x), None

    x, _ = lax.scan(body, x, params["layers"])
    h = L.apply_norm(cfg, params["final_ln"], x)
    return h @ params["embed"].T, {"aux_loss": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    e = cfg.encdec
    c = L.init_kv_cache(cfg, batch, max_len, dtype)
    c["bits"] = jnp.zeros((batch, max_len), jnp.uint32)
    shape = (cfg.num_layers, batch, e.encoder_seq, cfg.num_kv_heads,
             cfg.head_dim)
    c["cross_k"] = jnp.zeros(shape, dtype)
    c["cross_v"] = jnp.zeros(shape, dtype)
    return c


def prefill_cross(params, cfg: ModelConfig, cache, frames):
    """Run the encoder once and fill the per-layer cross K/V cache."""
    enc_out = encode(params, cfg, frames)

    def body(_, lp):
        B, Te = enc_out.shape[:2]
        k = (enc_out @ lp["cross"]["wk"]).reshape(
            B, Te, cfg.num_kv_heads, cfg.head_dim)
        v = (enc_out @ lp["cross"]["wv"]).reshape(
            B, Te, cfg.num_kv_heads, cfg.head_dim)
        return None, (k, v)

    _, (ck, cv) = lax.scan(body, None, params["layers"])
    cache = dict(cache)
    cache["cross_k"], cache["cross_v"] = ck, cv
    return cache


def decode_step(params, cfg: ModelConfig, cache, batch):
    B = batch["tokens"].shape[0]
    Tmax = cache["k"].shape[2]
    cur = batch["positions"][:, 0]
    idx = cur[0]
    kv_pos = jnp.broadcast_to(jnp.arange(Tmax, dtype=jnp.int32)[None],
                              (B, Tmax))
    self_mask = (kv_pos <= cur[:, None])[:, None, None, :]
    x = params["embed"][batch["tokens"]]
    x = x + sinusoid_pos(batch["positions"], cfg.d_model).astype(x.dtype)
    Te = cache["cross_k"].shape[2]
    enc_pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32)[None], (B, Te))
    cross_mask = jnp.ones((B, 1, 1, Te), bool)

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        store = {}

        def kv_override(k, v):
            nk, nv = L.cache_update(ck, cv, k, v, idx)
            store["k"], store["v"] = nk, nv
            return nk, nv

        h = L.apply_norm(cfg, lp["ln1"], x)
        a, _ = L.run_attention(lp["attn"], cfg, h, q_pos=batch["positions"],
                               kv_pos=kv_pos, mask=self_mask, rope=False,
                               kv_override=kv_override)
        x = x + a
        h = L.apply_norm(cfg, lp["ln_cross"], x)
        a, _ = L.run_attention(lp["cross"], cfg, h, q_pos=batch["positions"],
                               kv_pos=enc_pos, mask=cross_mask, rope=False,
                               kv_override=lambda k, v: (xk, xv))
        x = x + a
        h = L.apply_norm(cfg, lp["ln2"], x)
        x = x + L.run_mlp(lp["mlp"], h, "gelu")
        return x, (store["k"], store["v"])

    x, (nk, nv) = lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    h = L.apply_norm(cfg, params["final_ln"], x)
    logits = h @ params["embed"].T
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = nk, nv
    return logits, new_cache
