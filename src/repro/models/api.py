"""Uniform model API — dispatch on ``cfg.family``.

    init(key, cfg)                           -> params
    forward(params, cfg, batch)              -> (logits, aux)
    init_cache(cfg, batch_size, max_len)     -> cache
    decode_step(params, cfg, cache, batch)   -> (logits, cache)
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import mamba2, moe, transformer, vlm, whisper, xlstm

_FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "ssm": xlstm,
    "hybrid": mamba2,
    "audio": whisper,
    "vlm": vlm,
}


def module_for(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def init(key, cfg: ModelConfig):
    return module_for(cfg).init(key, cfg)


def forward(params, cfg: ModelConfig, batch):
    return module_for(cfg).forward(params, cfg, batch)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=None):
    return module_for(cfg).init_cache(cfg, batch_size, max_len, dtype)


def decode_step(params, cfg: ModelConfig, cache, batch):
    return module_for(cfg).decode_step(params, cfg, cache, batch)
