"""Concrete MLLM model code: encoder backbones + composition helpers.

``encoder_init``/``encoder_forward`` implement a bidirectional
transformer encoder backbone over stubbed frame/patch embeddings —
the EVA-CLIP / Whisper-encoder stand-ins of the paper's Table 1.
``build_paper_mllm`` assembles the paper's VLM / ALM / VALM evaluation
models (vision+audio encoders in S/M/L + a Llama-style LLM) through the
Cornstarch MultimodalModule.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.configs.paper_mllm import (audio_encoder_config, llm_config,
                                      vision_encoder_config)
from repro.models import layers as L


# ---------------------------------------------------------------------------
# Generic bidirectional encoder backbone (frontend stubbed)
# ---------------------------------------------------------------------------

def _enc_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.norm_init(cfg, cfg.d_model, dtype),
        "attn": L.attn_init(ks[0], cfg, dtype),
        "ln2": L.norm_init(cfg, cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype, gated=False),
    }


def encoder_init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {
        "layers": L.stacked_init(
            lambda k: _enc_layer_init(k, cfg, dtype), k1, cfg.num_layers),
        "final_ln": L.norm_init(cfg, cfg.d_model, dtype),
    }


def encoder_forward(params, cfg: ModelConfig, embeds):
    """embeds: [B, T_m, d_m] precomputed frontend output."""
    B, Tm, _ = embeds.shape
    pos = jnp.broadcast_to(jnp.arange(Tm, dtype=jnp.int32)[None], (B, Tm))
    full = jnp.ones((B, 1, Tm, Tm), bool)
    x = embeds

    def body(x, lp):
        def blk(x):
            h = L.apply_norm(cfg, lp["ln1"], x)
            a, _ = L.run_attention(lp["attn"], cfg, h, q_pos=pos, mask=full,
                                   rope=False)
            x = x + a
            h = L.apply_norm(cfg, lp["ln2"], x)
            return x + L.run_mlp(lp["mlp"], h, "gelu")
        if cfg.remat:
            blk = jax.checkpoint(blk)
        return blk(x), None

    x, _ = lax.scan(body, x, params["layers"])
    return L.apply_norm(cfg, params["final_ln"], x)


# ---------------------------------------------------------------------------
# Paper evaluation MLLMs (Table 1 zoo)
# ---------------------------------------------------------------------------

VISION_TOKENS = 576     # ~(1280x720 -> 24x24 patches), paper setup
AUDIO_TOKENS = 750      # 30 s clip at Whisper 25 fps after conv stride

def build_paper_mllm(kind: str = "valm", llm_size: str = "M",
                     vision_size: str = "S", audio_size: str = "S",
                     reduced: bool = False, text_len: int = 1024):
    """kind: vlm | alm | valm. Frozen encoders + frozen LLM + trainable
    projectors — the paper's §6 configuration."""
    from repro.core.modality import ModalityModule, MultimodalModule
    encoders: Dict[str, ModalityModule] = {}
    n_vis = 16 if reduced else VISION_TOKENS
    n_aud = 16 if reduced else AUDIO_TOKENS
    if kind in ("vlm", "valm"):
        encoders["vision"] = ModalityModule(
            "vision", vision_encoder_config(vision_size, reduced),
            modality_id=1, projector="linear", num_tokens=n_vis)
    if kind in ("alm", "valm"):
        encoders["audio"] = ModalityModule(
            "audio", audio_encoder_config(audio_size, reduced),
            modality_id=2, projector="linear", num_tokens=n_aud)
    mllm = MultimodalModule(
        encoders=encoders, llm_cfg=llm_config(llm_size, reduced),
        frozen_llm=True)
    for name in encoders:
        mllm.freeze(name, module=True, projector=False)
    return mllm
