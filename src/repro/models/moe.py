"""Mixture-of-Experts decoder family (qwen2-moe-a2.7b, deepseek-moe-16b).

Fine-grained MoE with shared experts (DeepSeekMoE, arXiv:2401.06066;
Qwen1.5-MoE): each layer = GQA attention + [shared experts (always-on
dense MLP) + routed experts (top-k)].

Two dispatch backends:

* ``capacity`` (production, expert-parallel): GShard-style fixed-capacity
  scatter. Tokens are assigned slot positions inside their expert's
  buffer via a cumulative count; overflow beyond
  ``C = ceil(T*K/E * capacity_factor)`` is dropped (standard TPU MoE).
  Expert weights and buffers shard over the ``model`` mesh axis (expert
  parallelism); compute is ``E × C × d × d_e`` batched matmuls on the
  MXU. HLO FLOPs ≈ active-expert FLOPs × capacity_factor — this is what
  the roofline's MODEL_FLOPS/HLO_FLOPs ratio measures for MoE.
* ``dense`` (exact, for tests/smoke): every expert computes every token,
  combined with routing weights — O(E/K) more FLOPs, bitwise-checkable
  against the router math.

Router aux loss: Switch-style load-balance loss
``E * Σ_e f_e · p_e`` (f = fraction of tokens routed to e, p = mean
router prob of e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _moe_ffn_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 5)
    d, de = cfg.d_model, m.d_expert
    ep = m.num_experts_padded   # dummy tail experts: routed-to never
    p = {
        "router": L.dense_init(ks[0], d, m.num_experts, dtype),
        # stacked expert weights [E_pad, d, de] / [E_pad, de, d]
        "w_gate": (jax.random.normal(ks[1], (ep, d, de)) * 0.02
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (ep, d, de)) * 0.02
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (ep, de, d)) * 0.02
                   ).astype(dtype),
    }
    if m.num_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, de * m.num_shared_experts, dtype,
                                 gated=True)
    return p


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    m = cfg.moe
    k_dense, k_moe = jax.random.split(key)
    # deepseek-moe: leading dense layer(s) kept out of the homogeneous scan
    moe_cfg = cfg.replace(num_layers=cfg.num_layers - m.first_dense_layers)
    params = T.init(k_moe, moe_cfg,
                    ffn_init=lambda k: _moe_ffn_init(k, cfg, dtype))
    if m.first_dense_layers:
        params["dense_layers"] = L.stacked_init(
            lambda k: T._layer_init(k, cfg, dtype), k_dense,
            m.first_dense_layers)
    return params


# ---------------------------------------------------------------------------
# Routed-expert dispatch
# ---------------------------------------------------------------------------

def router_probs(lp, h, cfg: ModelConfig):
    m = cfg.moe
    logits = (h @ lp["router"]).astype(jnp.float32)       # [B,T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, m.top_k)                    # [B,T,K]
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)   # renormalize
    return probs, w, idx


def aux_loss(probs, idx, cfg: ModelConfig):
    m = cfg.moe
    E = m.num_experts
    # scatter-add histogram instead of a [B,T,K,E] one-hot (memory!)
    n = idx.size // m.top_k
    f = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / n
    p = jnp.mean(probs.reshape(-1, E), axis=0)            # mean prob
    return E * jnp.sum(f * p) * m.router_aux_coef


def _dense_dispatch(lp, h, w, idx, cfg: ModelConfig):
    """Exact reference: all experts on all tokens, weighted combine."""
    m = cfg.moe
    # [E,B,T,de]
    g = jnp.einsum("btd,edf->ebtf", h, lp["w_gate"])
    u = jnp.einsum("btd,edf->ebtf", h, lp["w_up"])
    act = jax.nn.silu(g) * u
    out_e = jnp.einsum("ebtf,efd->ebtd", act, lp["w_down"])  # [E,B,T,d]
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=h.dtype)  # [B,T,K,E]
    weight = jnp.einsum("btke,btk->ebt", onehot, w.astype(h.dtype))
    return jnp.einsum("ebt,ebtd->btd", weight, out_e)


def _capacity_dispatch(lp, h, w, idx, cfg: ModelConfig):
    """GShard-style fixed-capacity scatter dispatch, **row-local**:
    slot assignment / scatter / gather happen within each batch row, so
    every buffer keeps the (data-sharded) batch dimension — no global
    [B·T·K, ·] tensors that GSPMD would have to replicate. This was
    §Perf iteration 1 for qwen2-moe train_4k: the original global
    dispatch cost 280 GB/device and 6.5 s of collective time; row-local
    dispatch shards cleanly (see EXPERIMENTS.md)."""
    m = cfg.moe
    B, T, d = h.shape
    K, E = m.top_k, m.num_experts_padded
    cap = int((T * K / m.num_experts) * m.capacity_factor) + 1

    idx_f = idx.reshape(B, T * K)               # expert id per (token,k)
    w_f = w.reshape(B, T * K)
    tok_f = jnp.broadcast_to(
        jnp.repeat(jnp.arange(T), K)[None], (B, T * K))

    onehot = jax.nn.one_hot(idx_f, E, dtype=jnp.int32)      # [B, T*K, E]
    pos_in_expert = jnp.cumsum(onehot, axis=1) * onehot     # 1-based
    slot = jnp.sum(pos_in_expert, axis=-1) - 1              # [B, T*K]
    keep = (slot >= 0) & (slot < cap)
    slot_c = jnp.clip(slot, 0, cap - 1)

    def scatter_row(hrow, idx_r, slot_r, keep_r, tok_r):
        src = jnp.where(keep_r[:, None], hrow[tok_r], 0).astype(hrow.dtype)
        return jnp.zeros((E, cap, d), hrow.dtype).at[idx_r, slot_r].add(src)

    buf = jax.vmap(scatter_row)(h, idx_f, slot_c, keep, tok_f)  # [B,E,c,d]
    # §Perf iteration 2 (qwen2-moe train_4k): GSPMD replicates the
    # vmapped scatter-add without an explicit constraint (43 GB
    # all-gathers + 86 GB backward all-reduces per layer at the
    # production mesh). Pin the dispatch buffers to the data axis.
    from repro.launch import sharding as shd
    # E-and-B 2-D sharding: batch over data, experts over model (true
    # expert parallelism when E_pad % model == 0; §Perf iteration 3)
    buf = shd.constrain(buf, "dp", "model", None, None)

    # expert FFN as batched matmul on the stacked expert dim
    g = jnp.einsum("becd,edf->becf", buf, lp["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, lp["w_up"])
    act = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", act, lp["w_down"])
    out_buf = shd.constrain(out_buf, "dp", "model", None, None)

    def gather_row(ob, idx_r, slot_r, keep_r, tok_r, w_r):
        g = ob[idx_r, slot_r]                               # [T*K, d]
        g = jnp.where(keep_r[:, None], g, 0)
        return jnp.zeros((T, d), ob.dtype).at[tok_r].add(
            g * w_r[:, None].astype(ob.dtype))

    combined = jax.vmap(gather_row)(out_buf, idx_f, slot_c, keep, tok_f,
                                    w_f)
    combined = shd.constrain(combined, "dp", None, None)
    return combined


def _shardmap_dispatch(lp, h, w, idx, cfg: ModelConfig, mesh, dp_axes):
    """Perf iteration A4: expert-parallel dispatch as an explicit
    shard_map — scatter/gather run *locally* per device (GSPMD's
    scatter partitioner, which replicated the buffers, never sees
    them). Each model-axis rank owns E_pad/model experts and computes
    only tokens routed to them from its (model-replicated) activation
    shard; one psum over ``model`` combines the outputs. Per-layer
    collective traffic drops to one [B/dp, T, d] all-reduce."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, T, d = h.shape
    K, E = m.top_k, m.num_experts_padded
    cap = int((T * K / m.num_experts) * m.capacity_factor) + 1

    # slot assignment is deterministic and model-replicated: compute it
    # once outside so every rank agrees
    idx_f = idx.reshape(B, T * K)
    w_f = w.reshape(B, T * K).astype(h.dtype)
    tok_f = jnp.broadcast_to(jnp.repeat(jnp.arange(T), K)[None], (B, T * K))
    onehot = jax.nn.one_hot(idx_f, E, dtype=jnp.int32)
    pos_in_expert = jnp.cumsum(onehot, axis=1) * onehot
    slot = jnp.sum(pos_in_expert, axis=-1) - 1
    keep_cap = (slot >= 0) & (slot < cap)
    slot_c = jnp.clip(slot, 0, cap - 1)

    def body(h_l, wg_l, wu_l, wd_l, idx_l, slot_l, keep_l, tok_l, wf_l):
        e_local = wg_l.shape[0]
        j = jax.lax.axis_index("model")
        idx_rel = idx_l - j * e_local
        mine = keep_l & (idx_rel >= 0) & (idx_rel < e_local)
        idx_rel = jnp.clip(idx_rel, 0, e_local - 1)

        def scatter_row(hrow, ir, sr, kr, tr):
            src = jnp.where(kr[:, None], hrow[tr], 0).astype(hrow.dtype)
            return jnp.zeros((e_local, cap, hrow.shape[-1]),
                             hrow.dtype).at[ir, sr].add(src)

        buf = jax.vmap(scatter_row)(h_l, idx_rel, slot_l, mine, tok_l)
        g = jnp.einsum("becd,edf->becf", buf, wg_l)
        u = jnp.einsum("becd,edf->becf", buf, wu_l)
        act = jax.nn.silu(g) * u
        ob = jnp.einsum("becf,efd->becd", act, wd_l)

        def gather_row(ob_r, ir, sr, kr, tr, wr):
            gbuf = ob_r[ir, sr]
            gbuf = jnp.where(kr[:, None], gbuf, 0)
            return jnp.zeros((T, ob_r.shape[-1]), ob_r.dtype).at[tr].add(
                gbuf * wr[:, None])

        out_l = jax.vmap(gather_row)(ob, idx_rel, slot_l, mine, tok_l,
                                     wf_l)
        return jax.lax.psum(out_l, "model")

    dp = P(dp_axes, None, None)
    ep = P("model", None, None)
    tk = P(dp_axes, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(dp, ep, ep, ep, tk, tk, tk, tk, tk),
        out_specs=dp, check_rep=False,
    )(h, lp["w_gate"], lp["w_up"], lp["w_down"], idx_f, slot_c, keep_cap,
      tok_f, w_f)


def _pick_dispatch(lp, h, w, idx, cfg: ModelConfig):
    m = cfg.moe
    if m.backend == "dense":
        return _dense_dispatch(lp, h, w, idx, cfg)
    from repro.launch import sharding as shd
    mesh = shd._CURRENT_MESH
    rules = shd.active()
    if mesh is not None and rules is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        msize = sizes.get("model", 1)
        dpsize = 1
        for a in rules.dp:
            dpsize *= sizes.get(a, 1)
        if msize > 1 and m.num_experts_padded % msize == 0 and \
                h.shape[0] % dpsize == 0:
            return _shardmap_dispatch(lp, h, w, idx, cfg, mesh, rules.dp)
    return _capacity_dispatch(lp, h, w, idx, cfg)


def moe_ffn(lp, h, cfg: ModelConfig):
    """Full MoE FFN: shared experts + routed top-k. Returns (out, aux)."""
    m = cfg.moe
    probs, w, idx = router_probs(lp, h, cfg)
    routed = _pick_dispatch(lp, h, w, idx, cfg)
    out = routed
    if m.num_shared_experts:
        out = out + L.run_mlp(lp["shared"], h, cfg.act)
    return out, aux_loss(probs, idx, cfg)


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

def _ffn_hook(cfg: ModelConfig):
    def ffn(lp, h, layer_idx):
        return moe_ffn(lp["mlp"], h, cfg)
    return ffn


def _run_dense_prefix(params, cfg: ModelConfig, x, batch):
    """Leading dense layers (deepseek-moe style), outside the MoE scan."""
    m = cfg.moe
    if not m.first_dense_layers:
        return x

    def body(x, xs):
        lp, i = xs

        def blk(x):
            out, _, _ = T._block(cfg, lp, x, batch, i, None)
            return out
        if cfg.remat:
            blk = jax.checkpoint(blk)
        return blk(x), None

    x, _ = lax.scan(body, x,
                    (params["dense_layers"], jnp.arange(m.first_dense_layers)))
    return x


def hidden(params, cfg: ModelConfig, batch):
    m = cfg.moe
    x = T.embed_tokens(params, cfg, batch)
    x = _run_dense_prefix(params, cfg, x, batch)
    moe_cfg = cfg.replace(num_layers=cfg.num_layers - m.first_dense_layers)
    ffn = _ffn_hook(cfg)

    def body(carry, xs):
        x, aux = carry
        lp, i = xs

        def blk(x):
            return T._block(moe_cfg, lp, x, batch, i, ffn)
        if cfg.remat:
            blk = jax.checkpoint(blk)
        x, a, _ = blk(x)
        return (x, aux + a), None

    (x, aux), _ = lax.scan(
        body, (x, jnp.float32(0.0)),
        (params["layers"], jnp.arange(moe_cfg.num_layers)))
    return L.apply_norm(cfg, params["final_ln"], x), {"aux_loss": aux}


def forward(params, cfg: ModelConfig, batch):
    h, aux = hidden(params, cfg, batch)
    return T.unembed(params, cfg, h), aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    m = cfg.moe
    c = L.init_kv_cache(cfg, batch, max_len, dtype,
                        num_layers=cfg.num_layers - m.first_dense_layers)
    c["bits"] = jnp.zeros((batch, max_len), jnp.uint32)
    if m.first_dense_layers:
        c["dense"] = L.init_kv_cache(cfg, batch, max_len, dtype,
                                     num_layers=m.first_dense_layers)
    return c


def decode_step(params, cfg: ModelConfig, cache, batch):
    m = cfg.moe
    moe_cfg = cfg.replace(num_layers=cfg.num_layers - m.first_dense_layers)
    if not m.first_dense_layers:
        return T.decode_step(params, moe_cfg, cache, batch,
                             ffn=_ffn_hook(cfg))

    # run dense prefix layers with their own cache slice, then the MoE scan
    B = batch["tokens"].shape[0]
    x = T.embed_tokens(params, cfg, batch)
    Tmax = cache["k"].shape[2]
    cur = batch["positions"][:, 0]
    kv_pos = jnp.broadcast_to(jnp.arange(Tmax, dtype=jnp.int32)[None],
                              (B, Tmax))
    from repro.core import bam
    q_bits = batch.get("bits")
    if q_bits is None:
        q_bits = jnp.full((B, 1), bam.text_token(), jnp.uint32)
    cache_bits = jnp.where(
        kv_pos < cur[:, None], cache["bits"],
        jnp.where(kv_pos == cur[:, None],
                  jnp.broadcast_to(q_bits, kv_pos.shape), jnp.uint32(0)))
    idx = cur[0]
    mask = bam.allowed_mask(q_bits, cache_bits, batch["positions"],
                            kv_pos)[:, None]

    def dense_body(x, xs):
        lp, ck, cv = xs
        store = {}

        def kv_override(k, v):
            nk, nv = L.cache_update(ck, cv, k, v, idx)
            store["k"], store["v"] = nk, nv
            return nk, nv

        h = L.apply_norm(cfg, lp["ln1"], x)
        attn_out, _ = L.run_attention(
            lp["attn"], cfg, h, q_pos=batch["positions"], kv_pos=kv_pos,
            mask=mask, kv_override=kv_override)
        x = x + attn_out
        h = L.apply_norm(cfg, lp["ln2"], x)
        out, _ = T._default_ffn(lp, h, cfg)
        return x + out, (store["k"], store["v"])

    x, (dk, dv) = lax.scan(
        dense_body, x,
        (params["dense_layers"], cache["dense"]["k"], cache["dense"]["v"]))

    sub = {"embed": params["embed"], "layers": params["layers"],
           "final_ln": params["final_ln"]}
    if "unembed" in params:
        sub["unembed"] = params["unembed"]
    # moe scan consumes pre-embedded hidden: pass via inputs_embeds override
    moe_batch = dict(batch)
    moe_batch["inputs_embeds"] = x
    moe_batch["embed_mask"] = jnp.ones(batch["tokens"].shape, bool)
    moe_cache = {"k": cache["k"], "v": cache["v"], "bits": cache["bits"]}
    logits, new_moe_cache = T.decode_step(sub, moe_cfg, moe_cache, moe_batch,
                                          ffn=_ffn_hook(cfg))
    new_cache = dict(new_moe_cache)
    new_cache["dense"] = {"k": dk, "v": dv}
    return logits, new_cache
