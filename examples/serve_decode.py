"""Continuous-batching serving demo over the paged BAM KV cache.

Five requests (staggered lengths + one multimodal prompt) share three
decode rows of a ``ServingEngine``: requests admit as rows free up,
prefill writes K/V straight into pages, every tick decodes one token
per occupied row, and finished requests return their pages to the
pool. The gemma2-style config exercises per-layer sliding windows
(local/global alternation) on the decode path.

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np

import jax

from repro.configs.base import get_config
from repro.core import bam
from repro.models import api
from repro.serving import ServingEngine


def main():
    cfg = get_config("gemma2-9b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, num_pages=64, page_size=8,
                        max_batch=3, attn="xla")

    rng = np.random.default_rng(0)
    plans = [(12, 8), (5, 10), (9, 6), (14, 7)]
    rids = [eng.submit(rng.integers(1, cfg.vocab_size, size=n),
                       max_new_tokens=m) for n, m in plans]
    # a multimodal request: text prompt around a modality-1 stream,
    # generated text keeps attending the image tokens
    bits, pos = bam.build_sample_bits(
        [("text", 0, 4), ("mod", 1, 8), ("text", 0, 4)], 16)
    rids.append(eng.submit(np.arange(1, 17), bits=bits, positions=pos,
                           max_new_tokens=6,
                           gen_bits=bam.text_token((1,))))
    want = [m for _, m in plans] + [6]

    tick = 0
    while eng.pending:
        tick += 1
        emitted = eng.step()
        if emitted:
            print(f"tick {tick:2d}: " + "  ".join(
                f"r{r}->{t}" for r, t in sorted(emitted.items())))

    for rid, n in zip(rids, want):
        got = eng.requests[rid].generated
        assert len(got) == n, (rid, got)
        print(f"request {rid}: {got}")
    # every page came back to the pool
    assert eng.table.num_free == eng.table.num_pages - 1
    print(f"served {len(rids)} requests on {eng.max_batch} rows "
          f"in {tick} ticks — serve_decode OK")


if __name__ == "__main__":
    main()
