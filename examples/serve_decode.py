"""Batched decode serving demo: prefill a prompt batch, then stream
greedy tokens from the KV cache (the decode_32k dry-run path at toy
scale, incl. a gemma2-style sliding-window config).

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import api
from repro.training import steps


def main():
    cfg = get_config("gemma2-9b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    B, prompt_len, gen_len, max_len = 4, 12, 12, 32
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, prompt_len)),
                         jnp.int32)

    serve = jax.jit(steps.make_serve_step(cfg), donate_argnums=(1,))
    cache = api.init_cache(cfg, B, max_len)

    # prefill token-by-token (a fused prefill kernel is the XLA forward;
    # this exercises the serving cache path end to end)
    tok = prompt[:, :1]
    for i in range(prompt_len):
        batch = {"tokens": prompt[:, i:i + 1],
                 "positions": jnp.full((B, 1), i, jnp.int32)}
        tok, cache = serve(params, cache, batch)

    generated = []
    cur = tok[:, None]
    for i in range(prompt_len, prompt_len + gen_len):
        batch = {"tokens": cur,
                 "positions": jnp.full((B, 1), i, jnp.int32)}
        tok, cache = serve(params, cache, batch)
        cur = tok[:, None]
        generated.append(np.asarray(tok))
    gen = np.stack(generated, axis=1)
    print(f"served batch={B}: generated {gen.shape[1]} tokens/row")
    print("sample row 0:", gen[0].tolist())
    assert gen.shape == (B, gen_len)
    print("serve_decode OK")


if __name__ == "__main__":
    main()
