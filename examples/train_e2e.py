"""End-to-end driver example (deliverable b): trains the ~125M-param
xlstm-125m on the synthetic LM stream for a few hundred steps via the
production train driver. On this 1-core CPU container a full run takes
a while; pass --steps to shorten.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import sys

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="xlstm-125m")
    args = ap.parse_args()
    res = train.main([
        "--arch", args.arch, "--steps", str(args.steps),
        "--seq", "128", "--batch", "2", "--vocab", "2048",
        "--log-every", "10", "--ckpt-dir", "ckpts/e2e",
    ])
    assert res["last_loss"] < res["first_loss"], res
    print("train_e2e OK")


if __name__ == "__main__":
    main()
