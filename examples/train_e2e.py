"""End-to-end driver example (deliverable b): the launch-script flow
of the typed parallel API. Searches one joint PP x CP plan with
``parallelize()``, persists it as JSON (what a cluster launch script
would cache), then trains the reduced paper VLM through the production
driver under ``--plan`` — the driver reloads and validates the plan
before any step runs.

    PYTHONPATH=src python examples/train_e2e.py [--steps 120]
    PYTHONPATH=src python examples/train_e2e.py --arch xlstm-125m  # LM mode
"""
import argparse
import os

import numpy as np

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--mllm", default="vlm", choices=["vlm", "alm",
                                                      "valm"])
    ap.add_argument("--arch", default=None,
                    help="train an LM architecture instead (no plan)")
    args = ap.parse_args()

    if args.arch:
        res = train.main([
            "--arch", args.arch, "--steps", str(args.steps),
            "--seq", "128", "--batch", "2", "--vocab", "2048",
            "--log-every", "10", "--ckpt-dir", "ckpts/e2e",
        ])
    else:
        from repro.models.mllm import build_paper_mllm
        from repro.parallel import (ClusterSpec, MLLMParallelPlan,
                                    WorkloadShape, parallelize)
        seq = 64
        mllm = build_paper_mllm(args.mllm, reduced=True, text_len=seq)
        # ft1 fine-tune: frozen encoders + trainable LLM — the
        # scenario where the zero-bubble schedules' deferred W passes
        # actually have work (and the loss can actually move)
        mllm.freeze("llm", module=False)
        plan = parallelize(
            mllm, ClusterSpec(num_devices=4, cp_size=2),
            WorkloadShape(text_len=seq, num_microbatches=8,
                          microbatch_size=2, block_size=8))
        print(plan.describe())
        os.makedirs("ckpts/e2e", exist_ok=True)
        plan_path = "ckpts/e2e/plan.json"
        plan.save(plan_path)
        assert MLLMParallelPlan.load(plan_path) == plan
        res = train.main([
            "--mllm", args.mllm, "--reduced", "--steps", str(args.steps),
            "--seq", str(seq), "--batch", "2", "--lr", "5e-3",
            "--log-every", "10", "--train-llm",
            "--plan", plan_path, "--ckpt-dir", "ckpts/e2e",
        ])
    # compare logged-loss means, not two noisy point samples
    losses = res["losses"]
    head = float(np.mean(losses[:3]))
    tail = float(np.mean(losses[-3:]))
    assert tail < head, (head, tail, losses)
    print(f"train_e2e OK (loss {head:.3f} -> {tail:.3f})")


if __name__ == "__main__":
    main()
