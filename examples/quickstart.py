"""Quickstart: build a Cornstarch MLLM from unimodal parts (the paper's
Listing 1), freeze encoders + LLM, plan its parallelization with ONE
typed call, train the projectors a few steps.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_mllm import llm_config, vision_encoder_config
from repro.core.modality import ModalityModule, MultimodalModule
from repro.data.synthetic import MultimodalDataset
from repro.optim import optimizer as opt
from repro.parallel import (ClusterSpec, MLLMParallelPlan, WorkloadShape,
                            parallelize)
from repro.training import steps


def main():
    # 1. load unimodal models (reduced sizes for a CPU demo)
    vis_cfg = vision_encoder_config("S", reduced=True)
    llm_cfg = llm_config("S", reduced=True)

    # 2. glue them into an MLLM (Listing 1)
    mllm = MultimodalModule(
        encoders={"vision": ModalityModule(
            "vision", vis_cfg, modality_id=1, projector="mlp",
            num_tokens=16)},
        llm_cfg=llm_cfg)
    mllm.freeze("vision", module=True, projector=False)
    mllm.freeze("llm", module=True)
    print("execution DAG antichains:", mllm.independent_sets())

    # 3. one typed call decides PP stages, pipeline schedule, virtual
    #    chunks AND the token-balanced CP distribution jointly
    plan = parallelize(
        mllm, ClusterSpec(num_devices=3, cp_size=2),
        WorkloadShape(text_len=64, num_microbatches=8, block_size=8))
    print(plan.describe())
    # the plan is plain data: cache it / ship it to a launch script
    assert MLLMParallelPlan.from_json(plan.to_json()) == plan
    executor = plan.apply(mllm)     # one-stage-per-device contract
    print(f"pipeline plan: {len(executor['graph'].stages)} stages, "
          f"simulated bubble {plan.schedule.bubble_fraction:.3f}")

    # 4. train the projector
    params = mllm.init(jax.random.PRNGKey(0))
    fmask = mllm.frozen_mask(params)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=50)
    state = opt.init(ocfg, params, fmask)
    step, _ = steps.make_mllm_train_step(mllm, ocfg)
    step = jax.jit(step)
    ds = iter(MultimodalDataset(
        vocab_size=llm_cfg.vocab_size, text_len=64, batch_size=2,
        encoder_dims={"vision": vis_cfg.d_model},
        encoder_tokens={"vision": 16}, modality_ids={"vision": 1}))
    for i, batch in zip(range(30), ds):
        params, state, m = step(params, state, batch)
        if i % 10 == 0 or i == 29:
            print(f"step {i:3d} loss {float(m['loss']):.4f}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
