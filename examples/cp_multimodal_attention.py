"""Multimodality-aware context parallelism end to end (paper §4.3):
build a multimodal sequence, plan LPT token distribution from BAM
workloads, and run all-gather CP attention on 4 host devices — checking
exactness against single-device attention and reporting the balance win
over zigzag.

    python examples/cp_multimodal_attention.py   (re-execs with 4 devices)
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.execv(sys.executable, [sys.executable] + sys.argv)

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bam, context_parallel as cp, distribution as dist
from repro.data.synthetic import random_multimodal_bits
from repro.models.layers import sdpa


def main():
    T, B, H, hd, G = 512, 1, 4, 32, 4
    bits_np, pos_np = random_multimodal_bits(T, "ee", seed=0)
    key = jax.random.PRNGKey(0)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
               for i in range(3))
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]

    for method in ("lpt", "zigzag"):
        plan = dist.plan_tokens(bits_np, pos_np, G, block_size=16,
                                method=method)
        loads = cp.simulate_rank_workloads(plan, bits_np, pos_np)
        print(f"{method:8s} rank workloads {loads.astype(int)} "
              f"imbalance {plan.imbalance:.3f}")

    plan = dist.plan_tokens(bits_np, pos_np, G, block_size=16, method="lpt")
    perm = cp.plan_permutation(plan, T)
    inv = cp.invert_perm(perm)
    mesh = jax.make_mesh((G,), ("cp",))
    args = [jnp.take(a, perm, axis=1) for a in (q, k, v)]
    bp = jnp.take(bits, perm, axis=1)
    pp_ = jnp.take(pos, perm, axis=1)
    out = cp.cp_attention(mesh, "cp", *args, bp, bp, pp_, pp_)
    out = jnp.take(out, inv, axis=1)
    ref = sdpa(q, k, v, bam.allowed_mask(bits, bits, pos, pos)[:, None])
    err = float(jnp.abs(out - ref).max())
    print(f"CP(4 ranks, LPT) vs reference max err: {err:.2e}")
    assert err < 5e-6
    print("cp_multimodal_attention OK")


if __name__ == "__main__":
    main()
